"""Model-level entry points: forward / prefill / decode_step.

Layers are executed with ``lax.scan`` over stacked parameters (compile time
independent of depth) with each body wrapped in ``jax.checkpoint`` (full
rematerialization — only layer-boundary activations survive to the backward
pass).  VLM backbones scan over (self x (g-1), cross) groups.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ArchConfig
from .layers import rms_norm, soft_cap, blockwise_attention
from .transformer import (Params, ShardFn, _attention, _noshard,
                          cross_layer_body, layer_body)


def _embed(params: Params, cfg: ArchConfig, tokens_or_embeds,
           compute_dtype) -> jax.Array:
    if cfg.input_mode == "embeddings":
        x = tokens_or_embeds.astype(compute_dtype)
    else:
        x = jnp.take(params["embed"], tokens_or_embeds, axis=0
                     ).astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    return x


def _unembed(params: Params, cfg: ArchConfig, x) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if cfg.final_softcap:
        logits = soft_cap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits


def _kinds(cfg: ArchConfig) -> jax.Array:
    return jnp.asarray(cfg.layer_kinds(), jnp.int32)


def _split_groups(tree, n_groups: int):
    """Reshape stacked (L, ...) leaves to (n_groups, L//n_groups, ...)."""
    return jax.tree.map(
        lambda a: a.reshape((n_groups, a.shape[0] // n_groups) + a.shape[1:]),
        tree)


# ---------------------------------------------------------------------------
# forward (teacher-forced logits — training / perplexity eval)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ArchConfig, tokens, *,
            enc: Optional[jax.Array] = None,
            compute_dtype=jnp.bfloat16,
            return_hidden: bool = False,
            shard: ShardFn = _noshard) -> jax.Array:
    b, s = tokens.shape[:2]
    x = _embed(params, cfg, tokens, compute_dtype)
    x = shard(x, "hidden")
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, xs):
        lp, kind = xs
        h, _, _ = layer_body(h, lp, cfg, q_pos=q_pos, is_global=kind,
                             compute_dtype=compute_dtype, shard=shard)
        return h, None

    body_ck = jax.checkpoint(body)

    if cfg.n_cross_layers:
        g = cfg.cross_attn_every
        n_groups = cfg.n_cross_layers
        self_groups = _split_groups(params["layers"], n_groups)
        kind_groups = _kinds(cfg).reshape(n_groups, g - 1)

        def group(h, xs):
            self_lps, kinds_g, cross_lp = xs
            h, _ = lax.scan(body_ck, h, (self_lps, kinds_g))
            h = jax.checkpoint(
                lambda hh, lp: cross_layer_body(
                    hh, lp, cfg, enc.astype(compute_dtype), q_pos=q_pos,
                    compute_dtype=compute_dtype, shard=shard))(h, cross_lp)
            return h, None

        x, _ = lax.scan(group, x,
                        (self_groups, kind_groups, params["cross_layers"]))
    else:
        x, _ = lax.scan(body_ck, x, (params["layers"], _kinds(cfg)))

    if return_hidden:
        return x
    logits = _unembed(params, cfg, x)
    return shard(logits, "logits")


# ---------------------------------------------------------------------------
# prefill: run the prompt, return caches sized `smax`
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, smax: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    n_self = cfg.n_self_layers if cfg.mixer != "mamba" else cfg.n_layers
    cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    hd = cfg.head_dim_of
    if cfg.mixer in ("attn", "hymba"):
        cache["k"] = jnp.zeros((n_self, batch, smax, cfg.n_kv, hd), dtype)
        cache["v"] = jnp.zeros((n_self, batch, smax, cfg.n_kv, hd), dtype)
    if cfg.mixer in ("mamba", "hymba"):
        di = cfg.ssm.expand * cfg.d_model
        kw = max(cfg.ssm.d_conv - 1, 1)
        cache["ssm_conv"] = jnp.zeros((n_self, batch, kw, di), dtype)
        cache["ssm_h"] = jnp.zeros((n_self, batch, di, cfg.ssm.d_state),
                                   jnp.float32)
    if cfg.n_cross_layers:
        cache["cross_k"] = jnp.zeros(
            (cfg.n_cross_layers, batch, cfg.encoder_len, cfg.n_kv, hd), dtype)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def cache_shapes(cfg: ArchConfig, batch: int, smax: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, smax, dtype))


def prefill(params: Params, cfg: ArchConfig, tokens, *, smax: int,
            enc: Optional[jax.Array] = None,
            compute_dtype=jnp.bfloat16,
            shard: ShardFn = _noshard) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (last-position logits (B, V), filled caches)."""
    b, s = tokens.shape[:2]
    x = _embed(params, cfg, tokens, compute_dtype)
    x = shard(x, "hidden")
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    cache = init_cache(cfg, b, smax, compute_dtype)
    has_attn = cfg.mixer in ("attn", "hymba")
    has_ssm = cfg.mixer in ("mamba", "hymba")

    def body(h, xs):
        lp, kind, kc, vc = xs
        zero_state = ({"conv": jnp.zeros_like(cache["ssm_conv"][0]),
                       "h": jnp.zeros_like(cache["ssm_h"][0])}
                      if has_ssm else None)
        h, new_cache, new_state = layer_body(
            h, lp, cfg, q_pos=q_pos, is_global=kind,
            cache=(kc, vc) if has_attn else None,
            cache_len=jnp.int32(0) if has_attn else None,
            ssm_state=zero_state,
            compute_dtype=compute_dtype, shard=shard)
        ys = {}
        if has_attn:
            ys["k"], ys["v"] = new_cache
        if has_ssm:
            ys["ssm_conv"] = new_state["conv"]
            ys["ssm_h"] = new_state["h"]
        return h, ys

    kc0 = cache.get("k")
    vc0 = cache.get("v")
    n_self = cfg.n_self_layers if cfg.mixer != "mamba" else cfg.n_layers
    dummy = jnp.zeros((n_self, 0)) if not has_attn else None

    if cfg.n_cross_layers:
        g = cfg.cross_attn_every
        n_groups = cfg.n_cross_layers
        self_groups = _split_groups(params["layers"], n_groups)
        kind_groups = _kinds(cfg).reshape(n_groups, g - 1)
        kc_g = _split_groups(kc0, n_groups)
        vc_g = _split_groups(vc0, n_groups)
        enc_c = enc.astype(compute_dtype)
        hd = cfg.head_dim_of

        def group(h, xs):
            self_lps, kinds_g, kcs, vcs, cross_lp = xs
            h, ys = lax.scan(jax.checkpoint(body), h,
                             (self_lps, kinds_g, kcs, vcs))
            # cross layer + cache its K/V
            ck = jnp.einsum("bsd,dh->bsh", enc_c, cross_lp["wk"].astype(
                compute_dtype)).reshape(b, -1, cfg.n_kv, hd)
            cv = jnp.einsum("bsd,dh->bsh", enc_c, cross_lp["wv"].astype(
                compute_dtype)).reshape(b, -1, cfg.n_kv, hd)
            h = jax.checkpoint(
                lambda hh, lp: cross_layer_body(
                    hh, lp, cfg, enc_c, q_pos=q_pos,
                    compute_dtype=compute_dtype, shard=shard))(h, cross_lp)
            ys["cross_k"] = ck
            ys["cross_v"] = cv
            return h, ys

        x, ys = lax.scan(group, x, (self_groups, kind_groups, kc_g, vc_g,
                                    params["cross_layers"]))
        cache["k"] = ys["k"].reshape((-1,) + ys["k"].shape[2:])
        cache["v"] = ys["v"].reshape((-1,) + ys["v"].shape[2:])
        cache["cross_k"] = ys["cross_k"]
        cache["cross_v"] = ys["cross_v"]
    else:
        xs = (params["layers"], _kinds(cfg),
              kc0 if has_attn else dummy, vc0 if has_attn else dummy)
        x, ys = lax.scan(jax.checkpoint(body), x, xs)
        for key in ("k", "v", "ssm_conv", "ssm_h"):
            if key in ys:
                cache[key] = ys[key]

    cache["len"] = jnp.asarray(s, jnp.int32)
    logits = _unembed(params, cfg, x[:, -1:])[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# decode: one token against the caches
# ---------------------------------------------------------------------------

def decode_step(params: Params, cfg: ArchConfig, token, cache, *,
                compute_dtype=jnp.bfloat16,
                shard: ShardFn = _noshard) -> Tuple[jax.Array, Dict[str, Any]]:
    """token: (B,) int32 (or (B, 1, D) embeddings).  Returns (logits (B,V),
    updated cache)."""
    if cfg.input_mode == "embeddings":
        b = token.shape[0]
        x = token.reshape(b, 1, -1).astype(compute_dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    else:
        b = token.shape[0]
        x = _embed(params, cfg, token.reshape(b, 1), compute_dtype)
    pos = cache["len"]
    q_pos = jnp.full((b, 1), pos, jnp.int32)
    has_attn = cfg.mixer in ("attn", "hymba")
    has_ssm = cfg.mixer in ("mamba", "hymba")

    def body(h, xs):
        lp, kind, kc, vc, sconv, sh = xs
        state = {"conv": sconv, "h": sh} if has_ssm else None
        h, new_cache, new_state = layer_body(
            h, lp, cfg, q_pos=q_pos, is_global=kind,
            cache=(kc, vc) if has_attn else None,
            cache_len=pos if has_attn else None,
            ssm_state=state, compute_dtype=compute_dtype, shard=shard)
        ys = {}
        if has_attn:
            ys["k"], ys["v"] = new_cache
        if has_ssm:
            ys["ssm_conv"] = new_state["conv"]
            ys["ssm_h"] = new_state["h"]
        return h, ys

    n_self = cfg.n_self_layers if cfg.mixer != "mamba" else cfg.n_layers
    dummy = jnp.zeros((n_self, 1))
    xs_all = (params["layers"], _kinds(cfg),
              cache.get("k", dummy), cache.get("v", dummy),
              cache.get("ssm_conv", dummy), cache.get("ssm_h", dummy))

    if cfg.n_cross_layers:
        g = cfg.cross_attn_every
        n_groups = cfg.n_cross_layers
        self_groups = _split_groups(params["layers"], n_groups)
        kind_groups = _kinds(cfg).reshape(n_groups, g - 1)
        kc_g = _split_groups(cache["k"], n_groups)
        vc_g = _split_groups(cache["v"], n_groups)
        hd = cfg.head_dim_of

        def group(h, xs):
            self_lps, kinds_g, kcs, vcs, cross_lp, ck, cv = xs
            h, ys = lax.scan(body, h, (self_lps, kinds_g, kcs, vcs,
                                       jnp.zeros((g - 1, 1)),
                                       jnp.zeros((g - 1, 1))))
            # cross attention against cached encoder K/V
            hq = rms_norm(h, cross_lp["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dh->bsh", hq, cross_lp["wq"].astype(
                compute_dtype)).reshape(b, 1, cfg.n_heads, hd)
            kv_pos = jnp.broadcast_to(
                jnp.arange(ck.shape[1], dtype=jnp.int32)[None],
                (b, ck.shape[1]))
            att = blockwise_attention(
                q, ck, cv, q_pos=q_pos, kv_pos=kv_pos, causal=False,
                softcap=cfg.attn_softcap, scale=cfg.attn_scale)
            att = att.reshape(b, 1, cfg.n_heads * hd)
            att = jnp.einsum("bsh,hd->bsd", att,
                             cross_lp["wo"].astype(compute_dtype))
            h = h + jnp.tanh(cross_lp["gate_attn"]).astype(h.dtype) \
                * att.astype(h.dtype)
            h2 = rms_norm(h, cross_lp["ln2"], cfg.norm_eps)
            from .transformer import _mlp
            h = h + jnp.tanh(cross_lp["gate_mlp"]).astype(h.dtype) * _mlp(
                h2, cross_lp, cfg, compute_dtype).astype(h.dtype)
            return h, ys

        x, ys = lax.scan(group, x, (self_groups, kind_groups, kc_g, vc_g,
                                    params["cross_layers"],
                                    cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache)
        new_cache["k"] = ys["k"].reshape((-1,) + ys["k"].shape[2:])
        new_cache["v"] = ys["v"].reshape((-1,) + ys["v"].shape[2:])
    else:
        x, ys = lax.scan(body, x, xs_all)
        new_cache = dict(cache)
        for key in ("k", "v", "ssm_conv", "ssm_h"):
            if key in ys:
                new_cache[key] = ys[key]

    new_cache["len"] = cache["len"] + 1
    logits = _unembed(params, cfg, x)[:, 0]
    return logits, new_cache
