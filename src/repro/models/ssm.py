"""Mamba-1 selective state-space mixer (falcon-mamba / hymba SSM heads).

Training/prefill runs a *chunked associative scan*: the diagonal recurrence
h_t = a_t * h_{t-1} + b_t is telescoped with ``jax.lax.associative_scan``
inside fixed-size time chunks, and the inter-chunk state is carried by a
``lax.scan`` — memory is O(chunk * d_inner * d_state) instead of
O(S * d_inner * d_state).  Decode is the O(1) single-step recurrence over a
carried (h, conv window) state.  The Pallas kernel in
repro.kernels.mamba_scan is the TPU-optimized inner loop; this module is the
portable reference used by the dry-run.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import SSMConfig


def _ssm_scan_chunked(a: jax.Array, bx: jax.Array, h0: jax.Array,
                      chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + bx_t.

    a, bx: (B, S, D, N); h0: (B, D, N).  Returns (h_all (B,S,D,N), h_last).
    """
    b, s, dd, n = a.shape
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ac = jnp.moveaxis(a.reshape(b, n_chunks, chunk, dd, n), 1, 0)
    bc = jnp.moveaxis(bx.reshape(b, n_chunks, chunk, dd, n), 1, 0)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, br + ar * bl

    def body(h, xs):
        a_c, b_c = xs                               # (B, chunk, D, N)
        aa, bb = lax.associative_scan(combine, (a_c, b_c), axis=1)
        h_all = aa * h[:, None] + bb                # prefix including carry
        return h_all[:, -1], h_all

    h_last, h_chunks = lax.scan(body, h0, (ac, bc))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(b, n_chunks * chunk, dd, n)
    return h_all[:, :s], h_last


def _ssm_scan_sequential(dt, bmat, cmat, xi, a, h0):
    """HBM-minimal recurrence: one sequential ``lax.scan`` over time, state
    expanded per step, y contracted per step — nothing with an (S, D, N)
    or even (chunk, D, N) extent ever reaches HBM.  This is the XLA-level
    expression of kernels/mamba_scan.py's VMEM strategy; on real TPUs the
    Pallas kernel replaces it (per-step loop overhead is not modeled by the
    dry-run roofline — see EXPERIMENTS.md §Perf notes).

    dt, xi: (B,S,di); bmat, cmat: (B,S,N); a: (di,N); h0: (B,di,N).
    """
    def step(h, xs):
        dt_t, b_t, c_t, x_t = xs              # (B,di) (B,N) (B,N) (B,di)
        da = jnp.exp(dt_t.astype(jnp.float32)[..., None] * a[None])
        h = da * h + (dt_t * x_t).astype(jnp.float32)[..., None] \
            * b_t.astype(jnp.float32)[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y_t

    to_t = lambda x: jnp.swapaxes(x, 0, 1)    # (S, B, ...)
    h_last, y = lax.scan(step, h0, (to_t(dt), to_t(bmat), to_t(cmat),
                                    to_t(xi)))
    return jnp.swapaxes(y, 0, 1), h_last      # (B,S,di)


def _ssm_scan_streamed(dt, bmat, cmat, xi, a, h0, chunk: int = 256,
                       state_dtype=jnp.float32):
    """Streamed recurrence: the (B,S,D,N) discretized tensors are expanded
    chunk-by-chunk INSIDE the scan body and y is contracted immediately —
    nothing with an (S, D, N) extent ever reaches HBM (§Perf hillclimb;
    the XLA-level analogue of kernels/mamba_scan.py).

    dt, xi: (B,S,di); bmat, cmat: (B,S,N); a: (di,N); h0: (B,di,N).
    Returns y (B,S,di) f32, h_last.
    """
    b, s, di = dt.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

    def chunks(x):
        x = pad_t(x)
        return jnp.moveaxis(
            x.reshape((b, n_chunks, chunk) + x.shape[2:]), 1, 0)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, br + ar * bl

    def body(h, xs):
        dt_c, b_c, c_c, x_c = xs                 # (B,c,di) (B,c,N) x2 (B,c,di)
        da = jnp.exp(dt_c.astype(jnp.float32)[..., None]
                     * a[None, None]).astype(state_dtype)
        dbx = ((dt_c * x_c).astype(jnp.float32)[..., None]
               * b_c.astype(jnp.float32)[:, :, None, :]
               ).astype(state_dtype)                          # (B,c,di,N)
        aa, bb = lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = aa.astype(jnp.float32) * h[:, None] \
            + bb.astype(jnp.float32)
        y_c = jnp.einsum("bsdn,bsn->bsd", h_all,
                         c_c.astype(jnp.float32))
        return h_all[:, -1], y_c

    h_last, y_chunks = lax.scan(
        jax.checkpoint(body), h0,
        (chunks(dt), chunks(bmat), chunks(cmat), chunks(xi)))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(b, n_chunks * chunk, di)
    return y[:, :s], h_last


def mamba_mixer(x: jax.Array, params: Dict[str, jax.Array], ssm: SSMConfig,
                *, state: Optional[Dict[str, jax.Array]] = None,
                return_state: bool = False):
    """Mamba-1 block.  x: (B, S, d_model).

    params: in_proj (d, 2*di), conv_w (K, di), conv_b (di), x_proj
    (di, dt_rank+2N), dt_proj (dt_rank, di), dt_bias (di), A_log (di, N),
    D (di), out_proj (di, d).
    state (decode): {"conv": (B, K-1, di), "h": (B, di, N)}.
    """
    b, s, d = x.shape
    di = params["conv_w"].shape[1]
    n = ssm.d_state
    kw = params["conv_w"].shape[0]

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)               # (B,S,di) each

    # depthwise causal conv over time ------------------------------------
    if state is not None:
        prev = state["conv"]                        # (B, K-1, di)
        xi_pad = jnp.concatenate([prev, xi], axis=1)
        new_conv = xi_pad[:, -(kw - 1):] if kw > 1 else prev
    else:
        xi_pad = jnp.pad(xi, ((0, 0), (kw - 1, 0), (0, 0)))
        new_conv = xi_pad[:, -(kw - 1):] if kw > 1 else None
    conv = sum(xi_pad[:, i:i + s] * params["conv_w"][i][None, None]
               for i in range(kw))
    xi = jax.nn.silu(conv + params["conv_b"][None, None])

    # input-dependent SSM parameters ------------------------------------------
    proj = jnp.einsum("bsd,de->bse", xi, params["x_proj"])
    dt_rank = ssm.dt_rank_of(d)
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt, params["dt_proj"])
                         + params["dt_bias"][None, None])      # (B,S,di)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))          # (di, N)

    h0 = state["h"] if state is not None else jnp.zeros((b, di, n),
                                                        jnp.float32)
    if s == 1:                                     # decode fast path
        da = jnp.exp(dt.astype(jnp.float32)[..., None] * a[None, None])
        dbx = (dt * xi).astype(jnp.float32)[..., None] \
            * bmat.astype(jnp.float32)[:, :, None, :]
        h_last = da[:, 0] * h0 + dbx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h_last,
                       cmat[:, 0].astype(jnp.float32))[:, None]
    else:
        from .perf_flags import get_flags
        flags = get_flags()
        if flags.ssm_impl == "sequential":
            y, h_last = _ssm_scan_sequential(dt, bmat, cmat, xi, a, h0)
        elif flags.ssm_impl == "streamed":
            sdt = jnp.bfloat16 if flags.ssm_state_dtype == "bf16" \
                else jnp.float32
            y, h_last = _ssm_scan_streamed(
                dt, bmat, cmat, xi, a, h0, chunk=flags.ssm_chunk,
                state_dtype=sdt)
        else:
            # baseline: (B,S,di,N) discretized tensors fully materialized
            da = jnp.exp(dt.astype(jnp.float32)[..., None] * a[None, None])
            dbx = (dt * xi).astype(jnp.float32)[..., None] \
                * bmat.astype(jnp.float32)[:, :, None, :]      # (B,S,di,N)
            h_all, h_last = _ssm_scan_chunked(da, dbx, h0,
                                              chunk=flags.ssm_chunk)
            y = jnp.einsum("bsdn,bsn->bsd", h_all,
                           cmat.astype(jnp.float32))          # (B,S,di)
    y = y + xi.astype(jnp.float32) * params["D"][None, None]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsd,de->bse", y.astype(x.dtype), params["out_proj"])

    if return_state:
        new_state = {"conv": new_conv if new_conv is not None else
                     jnp.zeros((b, max(kw - 1, 1), di), x.dtype),
                     "h": h_last}
        return out, new_state
    return out
