"""Architecture configuration schema for the model zoo.

One frozen dataclass describes every assigned architecture (dense / MoE /
SSM / hybrid / VLM / audio backbones).  Exact per-arch values live in
``repro/configs/<id>.py``; reduced smoke variants derive via ``reduced()``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    n_shared: int = 0              # shared (always-on) experts
    d_shared: int = 0              # shared-expert hidden dim (total)
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # renormalize top-k probs (qwen)

    @property
    def n_experts_padded(self) -> int:
        """Experts padded up for even expert-parallel sharding (qwen2's 60
        experts pad to 64; the 4 pads are masked with -inf router logits)."""
        n = self.n_experts
        pad = 1
        while pad < n:
            pad *= 2
        return n if n % 16 == 0 else min(pad, ((n + 15) // 16) * 16)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                # d_inner = expand * d_model
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)

    def dt_rank_of(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank is not None else -(-d_model // 16)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attn-free archs
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # mixer layout ---------------------------------------------------------
    mixer: str = "attn"            # attn | mamba | hymba (parallel attn+ssm)
    layer_pattern: str = "G"       # repeating local/global string, e.g.
                                   # "LLLLLG" (gemma3 5:1), "LG" (gemma2)
    window: int = 0                # sliding-window size for 'L' layers
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None

    # attention details -----------------------------------------------------
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0      # gemma2: 50.0
    qk_norm: bool = False          # gemma3
    attn_scale: float = 0.0        # 0 -> 1/sqrt(head_dim)

    # mlp -------------------------------------------------------------------
    mlp: str = "swiglu"            # swiglu | gelu | geglu
    # embeddings / output ------------------------------------------------------
    tie_embeddings: bool = True
    final_softcap: float = 0.0     # gemma2: 30.0
    embed_scale: bool = False      # gemma: x *= sqrt(d_model)
    norm_eps: float = 1e-6
    # cross-attention (VLM backbone) -----------------------------------------
    cross_attn_every: int = 0      # insert 1 cross-attn layer per N layers
    encoder_len: int = 0           # stub patch/frame sequence length
    # frontend stub -------------------------------------------------------------
    input_mode: str = "tokens"     # tokens | embeddings (audio/vlm stub)
    # training ---------------------------------------------------------------
    max_seq_len: int = 131072

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_of(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def n_self_layers(self) -> int:
        if self.cross_attn_every:
            g = self.cross_attn_every
            return self.n_layers * (g - 1) // g
        return self.n_layers

    @property
    def n_cross_layers(self) -> int:
        return self.n_layers - self.n_self_layers if self.cross_attn_every else 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (or window/state-capped) long-context decode."""
        if self.mixer in ("mamba", "hymba"):
            return True
        # mostly-local alternating patterns are window-capped except for a
        # linear number of global-layer reads — linear, not quadratic
        return "L" in self.layer_pattern

    def layer_kinds(self) -> Tuple[int, ...]:
        """Per self-attn-layer flag: 1 = global attention, 0 = local."""
        pat = self.layer_pattern or "G"
        n = self.n_self_layers if self.mixer != "mamba" else self.n_layers
        return tuple(1 if pat[i % len(pat)] == "G" else 0 for i in range(n))

    def reduced(self, *, n_layers: int = 2, d_model: int = 64,
                n_heads: int = 0, d_ff: int = 128, vocab: int = 256,
                seq: int = 0) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = n_heads or max(2, min(4, self.n_heads or 2))
        kv = max(1, min(self.n_kv, heads)) if self.n_kv else heads
        while heads % kv:
            kv -= 1
        if self.cross_attn_every:
            # keep full (g-1 self + 1 cross) groups in the reduced model
            n_layers = self.cross_attn_every * max(
                1, n_layers // self.cross_attn_every)
        updates = dict(
            n_layers=n_layers, d_model=d_model, n_heads=heads if self.n_heads else 0,
            n_kv=kv if self.n_kv else 0, d_ff=d_ff, vocab=vocab,
            head_dim=(d_model // heads) if self.n_heads else 0,
            window=min(self.window, 16) if self.window else 0,
            encoder_len=min(self.encoder_len, 8) if self.encoder_len else 0,
            cross_attn_every=self.cross_attn_every,
            max_seq_len=max(seq, 64),
        )
        if self.moe is not None:
            updates["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(2, self.moe.top_k),
                d_expert=32, d_shared=32 if self.moe.n_shared else 0)
        if self.ssm is not None:
            updates["ssm"] = dataclasses.replace(self.ssm, d_state=4, d_conv=2)
        return dataclasses.replace(self, **updates)
