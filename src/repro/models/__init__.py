"""Model zoo: unified decoder LM for all assigned architectures."""

from .config import ArchConfig, MoEConfig, SSMConfig
from .model import (cache_shapes, decode_step, forward, init_cache, prefill)
from .transformer import init_params, layer_shapes, param_shapes

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "cache_shapes", "decode_step",
    "forward", "init_cache", "prefill", "init_params", "layer_shapes",
    "param_shapes",
]
