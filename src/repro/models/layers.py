"""Transformer building blocks (pure JAX, bf16-compute friendly).

Attention is implemented *blockwise* (online-softmax over KV chunks, i.e.
flash-attention expressed in jnp/lax) so that 32k-token prefills never
materialize an (S x S) score matrix.  Supports GQA, causal masking, sliding
windows, logit soft-capping (gemma2), QK-norm (gemma3) and cross-attention
(VLM).  On real TPUs the Pallas kernel in repro.kernels.flash_attention
replaces the inner loop; the jnp path is the portable/dry-run reference.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
             *, plus_one: bool = False) -> jax.Array:
    from .perf_flags import get_flags
    dt = x.dtype
    if get_flags().norm_dtype == "bf16" and dt == jnp.bfloat16:
        # f32 variance accumulation, bf16 elementwise math — no f32 copy of
        # the (B,S,D) stream ever hits HBM (§Perf hillclimb)
        var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1,
                       keepdims=True)
        inv = lax.rsqrt(var + eps).astype(dt)
        scale = (1.0 + w).astype(dt) if plus_one else w.astype(dt)
        return x * inv * scale
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def soft_cap(x: jax.Array, cap: float) -> jax.Array:
    return (cap * jnp.tanh(x / cap)).astype(x.dtype)


# -- rotary embeddings ---------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int,
                theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions: (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# -- blockwise attention ----------------------------------------------------------

def _chunk_attn_update(carry, q, k_c, v_c, mask_c, scale, softcap):
    """Online-softmax update for one KV chunk.

    q: (B, Hq, Sq, D); k_c/v_c: (B, Hkv, C, D); mask_c: (B?, Sq, C) boolean
    carry = (acc (B,Hq,Sq,D), m (B,Hq,Sq), l (B,Hq,Sq))
    """
    acc, m, l = carry
    b, hq, sq, d = q.shape
    hkv = k_c.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k_c.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = s.reshape(b, hq, sq, -1)
    s = jnp.where(mask_c[:, None, :, :], s, -1e30)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pg = p.reshape(b, hkv, group, sq, -1)
    pv = jnp.einsum("bhgqk,bhkd->bhgqd", pg, v_c.astype(jnp.float32))
    acc_new = acc * alpha[..., None] + pv.reshape(b, hq, sq, d)
    return acc_new, m_new, l_new


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        q_pos: jax.Array, kv_pos: jax.Array,
                        causal: bool = True, window=None,
                        softcap: float = 0.0, scale: float = 0.0,
                        chunk: int = 512) -> jax.Array:
    """Flash-style attention in jnp.

    q: (B, Sq, Hq, D);  k/v: (B, Skv, Hkv, D);
    q_pos: (B, Sq) absolute positions; kv_pos: (B, Skv).
    window masks keys older than `window` positions (local attention); it
    may be a Python int or a traced scalar (per-layer local/global flags
    inside a scan become ``where(is_global, 2**30, w)``).  None/0 = full.
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    scale = scale or (1.0 / math.sqrt(d))
    qt = jnp.swapaxes(q, 1, 2)                       # (B,Hq,Sq,D)
    kt = jnp.swapaxes(k, 1, 2)                       # (B,Hkv,Skv,D)
    vt = jnp.swapaxes(v, 1, 2)

    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)

    hkv = kt.shape[1]
    kc = jnp.moveaxis(kt.reshape(b, hkv, n_chunks, chunk, d), 2, 0)
    vc = jnp.moveaxis(vt.reshape(b, hkv, n_chunks, chunk, d), 2, 0)
    pc = kv_pos.reshape(b, n_chunks, chunk).swapaxes(0, 1)   # (n,B,C)

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)

    use_window = window is not None and not (
        isinstance(window, int) and window == 0)

    def body(carry, xs):
        k_c, v_c, p_c = xs                       # (B,Hkv,C,D), (B,C)
        mask = p_c[:, None, :] >= 0              # (B,1,C) valid keys
        if causal:
            mask = mask & (p_c[:, None, :] <= q_pos[:, :, None])
        if use_window:
            mask = mask & (p_c[:, None, :] > q_pos[:, :, None] - window)
        carry = _chunk_attn_update(carry, qt, k_c, v_c, mask, scale, softcap)
        return carry, None

    # xs leaves have leading n_chunks axis; k_c arrives as (B,Hkv,C,D)
    (acc, m, l), _ = lax.scan(body, (acc0, m0, l0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


def blockwise_attention_qouter(q, k, v, *, q_pos, kv_pos, causal=True,
                               window=None, softcap=0.0, scale=0.0,
                               q_chunk=512, kv_chunk=512):
    """Flash loop order: scan over q-tiles, online-softmax accumulator per
    tile.  The (B,H,S,D) f32 accumulator of the kv-inner baseline round-trips
    HBM once per kv chunk; here it is (B,H,q_chunk,D), re-created per q-tile
    (§Perf hillclimb; mirrors kernels/flash_attention.py)."""
    b, sq, hq, d = q.shape
    q_chunk = min(q_chunk, sq)
    nq = -(-sq // q_chunk)
    pad = nq * q_chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=2 ** 30)
    qs = jnp.moveaxis(q.reshape(b, nq, q_chunk, hq, d), 1, 0)
    ps = jnp.moveaxis(q_pos.reshape(b, nq, q_chunk), 1, 0)

    def qbody(_, xs):
        q_c, p_c = xs
        out_c = blockwise_attention(q_c, k, v, q_pos=p_c, kv_pos=kv_pos,
                                    causal=causal, window=window,
                                    softcap=softcap, scale=scale,
                                    chunk=kv_chunk)
        return None, out_c

    _, outs = lax.scan(qbody, None, (qs, ps))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, hq, d)
    return out[:, :sq]


# -- MLPs ------------------------------------------------------------------------

def mlp_swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array
               ) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, wg)
    u = jnp.einsum("bsd,df->bsf", x, wu)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, wd).astype(x.dtype)


def mlp_gelu(x: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wi), approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, wo).astype(x.dtype)


def mlp_geglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array
              ) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, wg)
    u = jnp.einsum("bsd,df->bsf", x, wu)
    h = jax.nn.gelu(g, approximate=True) * u
    return jnp.einsum("bsf,fd->bsd", h, wd).astype(x.dtype)
