"""Performance-variant flags for the §Perf hillclimb.

The baseline (paper-faithful substrate) runs with all defaults; each
hillclimb iteration flips one flag, re-lowers, and re-derives the roofline
terms (EXPERIMENTS.md §Perf records hypothesis -> change -> before/after).
Flags are process-global so the dry-run CLI can set them without threading
through every call site.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass
class PerfFlags:
    # attention loop order: "kv_scan" = kv-chunk inner loop with full-S
    # accumulator (baseline); "q_outer" = scan q-chunks, accumulator per
    # q-tile (flash loop order — HBM-optimal)
    attention_impl: str = "kv_scan"
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512
    # SSM scan: "materialized" builds (B,S,D,N) da/dbx tensors (baseline);
    # "streamed" expands them chunk-by-chunk inside the scan body
    ssm_impl: str = "materialized"
    ssm_chunk: int = 256
    # dtype of the streamed associative-scan elements (da/dbx/h); bf16
    # halves the dominant SSM HBM traffic (A stays f32 in the exponent)
    ssm_state_dtype: str = "f32"
    # RMSNorm intermediate dtype: "f32" materializes an f32 copy (baseline);
    # "bf16" keeps elementwise math in bf16 with f32 variance accumulation
    norm_dtype: str = "f32"
    # cross-entropy: "full" materializes (B,S,V) f32 logsumexp (baseline);
    # "chunked" streams sequence chunks through the unembed+CE
    ce_impl: str = "full"
    ce_chunk: int = 512
    # MoE combine: "gather" reads the E-sharded expert output buffer via
    # gather (baseline); "replicated" all-gathers the expert outputs once
    # per layer and combines locally
    moe_combine: str = "gather"
    # MoE implementation: "pjit" (baseline, GSPMD-partitioned dispatch) or
    # "shard_map" (explicitly local dispatch per model-rank, E_loc experts
    # each, partial outputs psum'd over `model` — the production EP pattern)
    moe_impl: str = "pjit"
    # residual-stream sequence sharding (sequence parallelism): shard the
    # (B, S, D) carry's S dim over `model` between layers
    seq_shard: bool = False


_FLAGS = PerfFlags()
_MESH = None           # (mesh, batch_axes) registered by the launcher
_MODEL_AXIS = "model"


def get_flags() -> PerfFlags:
    return _FLAGS


def set_mesh(mesh, batch_axes) -> None:
    global _MESH
    _MESH = (mesh, tuple(batch_axes))


def get_mesh():
    return _MESH


def set_flags(**kw) -> PerfFlags:
    global _FLAGS
    _FLAGS = replace(_FLAGS, **kw)
    return _FLAGS


def reset_flags() -> PerfFlags:
    global _FLAGS
    _FLAGS = PerfFlags()
    return _FLAGS
