"""Sharded checkpointing with atomic manifests and resume-from-latest.

Layout::

    <dir>/step_000420.tmp/...      (write)
    <dir>/step_000420/             (atomic rename on completion)
        manifest.json              (tree structure, shapes, dtypes, step)
        <leaf-path>.npy            (one file per pytree leaf, per host)

On multi-host clusters each host writes the addressable shards of its local
devices (leaf files are suffixed with the host id); this CPU container is a
single host, so files carry shard 0.  Writes are crash-safe: a partially
written step directory never carries the final name, and ``latest_step``
only believes directories with a complete manifest.  Retention keeps the
most recent k checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    host_id: int = 0, keep: int = 3) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names: List[str] = []
    meta: List[Dict] = []
    for path, leaf in flat:
        name = _leaf_path(path)
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.int8, np.uint8, np.bool_, np.int16,
                             np.uint16, np.uint32, np.uint64, np.float16):
            arr = arr.astype(np.float32)      # bf16 & friends -> f32 on disk
        np.save(os.path.join(tmp, f"{name}.h{host_id}.npy"), arr)
        names.append(name)
        meta.append({"name": name, "shape": list(arr.shape),
                     "dtype": orig_dtype})
    manifest = {"step": step, "time": time.time(), "host": host_id,
                "leaves": meta, "treedef": str(treedef)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith("tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.endswith("tmp") or ".tmp" in d:
            continue
        if not os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            continue                      # incomplete -> crash during write
        try:
            s = int(d.split("_")[1])
        except ValueError:
            continue
        best = s if best is None else max(best, s)
    return best


def restore_checkpoint(ckpt_dir: str, step: int, like: Any, *,
                       host_id: int = 0) -> Any:
    """Restore into the structure (and shardings) of `like`."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        name = _leaf_path(path)
        arr = np.load(os.path.join(d, f"{name}.h{host_id}.npy"))
        target_dtype = getattr(leaf, "dtype", arr.dtype)
        val = jax.numpy.asarray(arr).astype(target_dtype)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            val = jax.device_put(val, sharding)
        leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, leaves)
