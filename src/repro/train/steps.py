"""Step builders: train_step / prefill_step / decode-serve_step.

These close over the (static) ArchConfig + optimizer config + optional
sharding callbacks and return pure functions suitable for ``jax.jit`` with
explicit in/out shardings — the objects the multi-pod dry-run lowers.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models.config import ArchConfig
from ..models.model import decode_step as model_decode
from ..models.model import forward, prefill
from ..models.transformer import _noshard
from .optimizer import AdamWConfig, OptState, adamw_update


def lm_loss(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            *, compute_dtype=jnp.bfloat16, shard=_noshard,
            z_loss: float = 1e-4) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ z-loss) over a batch.

    batch: {"inputs": (B,S) int32 or (B,S,D) float, "targets": (B,S) int32,
            optional "enc": (B,E,D)}.

    With perf flag ce_impl="chunked" the unembed + CE streams over sequence
    chunks under jax.checkpoint, so the (B, S, V) f32 logits tensor never
    materializes (§Perf hillclimb).
    """
    from ..models.perf_flags import get_flags
    flags = get_flags()
    if flags.ce_impl == "chunked":
        from ..models.model import _unembed
        x = forward(params, cfg, batch["inputs"], enc=batch.get("enc"),
                    compute_dtype=compute_dtype, shard=shard,
                    return_hidden=True)
        b, s, d = x.shape
        c = min(flags.ce_chunk, s - 1)
        n_chunks = -(-(s - 1) // c)
        pad = n_chunks * c - (s - 1)
        xp = jnp.pad(x[:, :-1], ((0, 0), (0, pad), (0, 0)))
        yp = jnp.pad(batch["targets"][:, 1:], ((0, 0), (0, pad)))
        wp = jnp.pad(jnp.ones((b, s - 1), jnp.float32),
                     ((0, 0), (0, pad)))
        xs = xp.reshape(b, n_chunks, c, d).swapaxes(0, 1)
        ys = yp.reshape(b, n_chunks, c).swapaxes(0, 1)
        ws = wp.reshape(b, n_chunks, c).swapaxes(0, 1)

        def chunk_ce(carry, xs_c):
            x_c, y_c, w_c = xs_c
            lg = _unembed(params, cfg, x_c).astype(jnp.float32)
            logz = jax.nn.logsumexp(lg, axis=-1)
            ll = jnp.take_along_axis(lg, y_c[..., None], axis=-1)[..., 0]
            ce_sum, z_sum = carry
            return (ce_sum + jnp.sum((logz - ll) * w_c),
                    z_sum + jnp.sum(jnp.square(logz) * w_c)), None

        (ce_sum, z_sum), _ = lax.scan(
            jax.checkpoint(chunk_ce), (jnp.float32(0), jnp.float32(0)),
            (xs, ys, ws))
        denom = b * (s - 1)
        ce = ce_sum / denom
        loss = ce + z_loss * z_sum / denom
        return loss, {"loss": ce}

    logits = forward(params, cfg, batch["inputs"], enc=batch.get("enc"),
                     compute_dtype=compute_dtype, shard=shard)
    lg = logits[:, :-1].astype(jnp.float32)
    labels = batch["targets"][:, 1:]
    logz = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - ll)
    loss = ce + z_loss * jnp.mean(jnp.square(logz))
    return loss, {"loss": ce}


def build_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                     *, microbatches: int = 1,
                     compute_dtype=jnp.bfloat16,
                     shard=_noshard,
                     grad_transform: Optional[Callable] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    microbatches > 1 accumulates gradients over equal batch slices via
    ``lax.scan`` (sequential, memory-bounded).  `grad_transform` hooks in
    gradient compression (sharding/compression.py).
    """

    def loss_fn(params, mb):
        return lm_loss(params, cfg, mb, compute_dtype=compute_dtype,
                       shard=shard)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = lax.scan(acc_body, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            aux = {"loss": loss}
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics.update(aux)
        return params, opt_state, metrics

    return train_step


def build_prefill_step(cfg: ArchConfig, *, smax: int,
                       compute_dtype=jnp.bfloat16, shard=_noshard):
    """Inference-prefill: logits for the last position + filled caches."""

    def prefill_step(params, batch):
        return prefill(params, cfg, batch["inputs"], smax=smax,
                       enc=batch.get("enc"), compute_dtype=compute_dtype,
                       shard=shard)

    return prefill_step


def build_decode_step(cfg: ArchConfig, *, compute_dtype=jnp.bfloat16,
                      shard=_noshard, greedy: bool = True):
    """Serving decode: one new token for every sequence in the batch."""

    def serve_step(params, token, cache):
        logits, cache = model_decode(params, cfg, token, cache,
                                     compute_dtype=compute_dtype,
                                     shard=shard)
        if cfg.input_mode == "embeddings":
            # audio backbone: the frontend consumes logits; return argmax id
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return serve_step
