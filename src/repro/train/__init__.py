"""Training substrate: optimizer, step builders, fault-tolerant trainer."""

from .optimizer import (AdamWConfig, OptState, adamw_update, init_opt_state,
                        lr_schedule, opt_state_shapes)
from .steps import (build_decode_step, build_prefill_step, build_train_step,
                    lm_loss)
from .trainer import Trainer, TrainerConfig, on_resize

__all__ = ["AdamWConfig", "OptState", "adamw_update", "init_opt_state",
           "lr_schedule", "opt_state_shapes", "build_decode_step",
           "build_prefill_step", "build_train_step", "lm_loss", "Trainer",
           "TrainerConfig", "on_resize"]
