"""AdamW with sharded state, implemented directly in JAX.

Optimizer state lives in the same sharding as the parameters (first/second
moments are elementwise), so no extra communication is introduced by the
update.  Moments default to bfloat16 — at 1000+ node scale the 8 bytes/param
saved dominate, and bf16 moments (+f32 update arithmetic) is standard
production practice on TPU.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.bfloat16


class OptState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: Any                   # pytree like params
    v: Any


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def opt_state_shapes(params_shapes, cfg: AdamWConfig) -> OptState:
    """ShapeDtypeStruct mirror (dry-run)."""
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree.map(zeros, params_shapes),
                    v=jax.tree.map(zeros, params_shapes))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to lr_min_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr_peak * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
