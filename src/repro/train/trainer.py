"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests/examples):

* **checkpoint/restart** — periodic sharded checkpoints with atomic
  manifests; on start the trainer resumes from the latest complete step
  (crash mid-write is invisible: incomplete dirs carry .tmp names);
* **failure retry** — a step that raises is retried from the last
  checkpoint up to ``max_restarts`` times (transient XLA/network faults at
  scale), with the data pipeline re-seeked by step index (deterministic);
* **straggler detection** — per-step wall-time EWMA; steps slower than
  ``straggler_factor`` x EWMA are logged with their host context.  On a real
  multi-pod deployment this feeds the controller that re-slices the pod
  (elastic re-mesh below); here it is surfaced in metrics;
* **elastic re-mesh hook** — ``on_resize(new_n_hosts)`` rebuilds the mesh /
  reshards params from a checkpoint: DP axes can shrink/grow between jobs
  because checkpoints are mesh-agnostic (full-array npy per leaf).
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint.checkpointer import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from ..data.pipeline import DataConfig, Prefetcher, make_source


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    max_restarts: int = 3
    straggler_factor: float = 2.5
    ewma_alpha: float = 0.1


@dataclass
class TrainerState:
    step: int = 0
    ewma_step_s: float = 0.0
    stragglers: List[int] = field(default_factory=list)
    restarts: int = 0


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 params: Any, opt_state: Any, data_cfg: DataConfig,
                 host_id: int = 0):
        self.cfg = cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.data_cfg = data_cfg
        self.host_id = host_id
        self.state = TrainerState()
        self.history: List[Dict[str, float]] = []

    # -- checkpoint plumbing -------------------------------------------------
    def _save(self, step: int) -> None:
        save_checkpoint(self.cfg.ckpt_dir, step,
                        {"params": self.params, "opt": self.opt_state},
                        host_id=self.host_id, keep=self.cfg.keep)

    def _try_resume(self) -> int:
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return 0
        tree = restore_checkpoint(self.cfg.ckpt_dir, last,
                                  {"params": self.params,
                                   "opt": self.opt_state},
                                  host_id=self.host_id)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        return last

    # -- main loop ---------------------------------------------------------------
    def run(self, *, fail_at: Optional[int] = None) -> TrainerState:
        """fail_at: inject a fault at that step (tests the restart path)."""
        os.makedirs(self.cfg.ckpt_dir, exist_ok=True)
        start = self._try_resume()
        self.state.step = start
        source = make_source(self.data_cfg)
        prefetch = Prefetcher(source, start_step=start)
        injected = {"armed": fail_at is not None}

        try:
            while True:
                # NOTE: pull explicitly — a `for ... in prefetch` iterator
                # would stay bound to a pre-restart prefetcher and deadlock
                step, batch = next(prefetch)
                if step >= self.cfg.total_steps:
                    break
                t0 = time.monotonic()
                try:
                    if injected["armed"] and step == fail_at:
                        injected["armed"] = False
                        raise RuntimeError("injected fault (test)")
                    batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                    self.params, self.opt_state, metrics = self.train_step(
                        self.params, self.opt_state, batch)
                except Exception:
                    self.state.restarts += 1
                    if self.state.restarts > self.cfg.max_restarts:
                        raise
                    prefetch.stop()
                    resumed = self._try_resume()
                    self.state.step = resumed
                    prefetch = Prefetcher(source, start_step=resumed)
                    continue

                dt = time.monotonic() - t0
                st = self.state
                if st.ewma_step_s == 0.0:
                    st.ewma_step_s = dt
                else:
                    a = self.cfg.ewma_alpha
                    if dt > self.cfg.straggler_factor * st.ewma_step_s:
                        st.stragglers.append(step)
                    st.ewma_step_s = (1 - a) * st.ewma_step_s + a * dt
                st.step = step + 1

                if (step + 1) % self.cfg.log_every == 0 or step == 0:
                    loss = float(metrics.get("loss", np.nan))
                    self.history.append({"step": step + 1, "loss": loss,
                                         "step_s": dt})
                if (step + 1) % self.cfg.ckpt_every == 0:
                    self._save(step + 1)
            if self.state.step % self.cfg.ckpt_every:
                self._save(self.state.step)
        finally:
            prefetch.stop()
        return self.state


def on_resize(ckpt_dir: str, like_tree: Any, *, host_id: int = 0) -> Any:
    """Elastic re-mesh: restore the latest checkpoint into a NEW sharding
    layout (`like_tree` carries the new shardings).  Checkpoints store full
    arrays, so any DP/TP reshape that preserves shapes is legal."""
    last = latest_step(ckpt_dir)
    if last is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    return restore_checkpoint(ckpt_dir, last, like_tree, host_id=host_id)
