"""Assigned-architecture registry: ``get_config(arch_id)``.

Each module defines ``CONFIG`` (exact published numbers, see the per-file
source tags) and the registry maps the assignment ids to them.  Reduced
smoke variants come from ``CONFIG.reduced()``.
"""

from __future__ import annotations

from typing import Dict, List

from ..models.config import ArchConfig


def _load(mod_name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


_REGISTRY: Dict[str, str] = {
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "musicgen-large": "musicgen_large",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "gemma3-27b": "gemma3_27b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gemma2-27b": "gemma2_27b",
    "llama3.2-1b": "llama3_2_1b",
}


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return _load(_REGISTRY[arch_id])
