"""llama-3.2-vision-90b  [vlm]  (hf:meta-llama/Llama-3.2-11B-Vision scaled;
assignment card: 100L d_model=8192 64H GQA kv=8 d_ff=28672 vocab=128256,
cross-attn image layers).

Backbone only: the vision tower is a stub — ``input_specs`` provides
precomputed patch embeddings (B, encoder_len, d_model).  One gated
cross-attention layer is inserted every 5 layers (80 self + 20 cross = 100).
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    mixer="attn",
    layer_pattern="G",
    rope_theta=500000.0,
    mlp="swiglu",
    tie_embeddings=False,
    cross_attn_every=5,
    encoder_len=1600,          # ~4 tiles x 400 patches, pre-projected stub
    max_seq_len=131072,
)
