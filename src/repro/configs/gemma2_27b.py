"""gemma2-27b  [dense]  (arXiv:2408.00118; assignment card: 46L
d_model=4608 32H GQA kv=16 d_ff=36864 vocab=256000 — local/global
alternating, logit softcaps).

Alternating 4096-token sliding-window and global layers; attention logits
soft-capped at 50, final logits at 30; attn scale 1/sqrt(d_model/n_heads) =
1/12 per the gemma2 reference (query pre-scaling).
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    mixer="attn",
    layer_pattern="LG",
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_scale=1.0 / (144.0 ** 0.5),   # (d_model/n_heads)^-0.5 = 144^-0.5
    rope_theta=10000.0,
    mlp="geglu",
    tie_embeddings=True,
    embed_scale=True,
    max_seq_len=8192,
)
