"""deepseek-coder-33b  [dense]  (arXiv:2401.14196; assignment card: 62L
d_model=7168 56H GQA kv=8 d_ff=19200 vocab=32256 — llama architecture).
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    mixer="attn",
    rope_theta=100000.0,
    mlp="swiglu",
    tie_embeddings=False,
    max_seq_len=16384,
)
