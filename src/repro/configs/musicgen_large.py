"""musicgen-large  [audio]  (arXiv:2306.05284; assignment card: 48L
d_model=2048 32H GQA kv=32 d_ff=8192 vocab=2048 — decoder-only over EnCodec
tokens).

Backbone only: the EnCodec tokenizer/delay-pattern frontend is a stub —
``input_specs`` provides precomputed frame embeddings (sum of the 4 codebook
embeddings), so ``input_mode="embeddings"``.  The LM head predicts one
2048-entry codebook (per-codebook heads are frontend territory).
MusicGen uses full MHA (kv == heads) and GELU MLPs, sinusoidal positions in
the original; we use RoPE as the positional backbone (noted in DESIGN.md).
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    mixer="attn",
    mlp="gelu",
    tie_embeddings=False,
    input_mode="embeddings",
    max_seq_len=32768,
)
