"""falcon-mamba-7b  [ssm]  (arXiv:2410.05355; assignment card: 64L
d_model=4096 attn-free d_ff=0 vocab=65024, ssm_state=16 — mamba1).

Pure Mamba-1 stack: every layer is norm -> mamba mixer -> residual (no
attention, no MLP; d_inner = 2 x d_model = 8192).  O(1)-state decode makes
this arch the canonical ``long_500k`` runner.
"""

from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=65024,
    mixer="mamba",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=True,
    max_seq_len=1 << 20,
)
