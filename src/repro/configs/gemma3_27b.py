"""gemma3-27b  [dense]  (hf:google/gemma-3-27b family; assignment card: 62L
d_model=5376 32H GQA kv=16 d_ff=21504 vocab=262144 — 5:1 local:global
alternation, 128k context).

Local layers use a 1024-token sliding window; every 6th layer is global.
QK-norm, GEGLU MLP, embedding scaling per the gemma family.  (Gemma3 uses a
different rope theta for global layers — single theta here, noted in
DESIGN.md.)
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    mixer="attn",
    layer_pattern="LLLLLG",
    window=1024,
    qk_norm=True,
    rope_theta=1000000.0,
    mlp="geglu",
    tie_embeddings=True,
    embed_scale=True,
    max_seq_len=131072,
)
