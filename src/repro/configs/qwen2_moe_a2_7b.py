"""qwen2-moe-a2.7b  [moe]  (hf:Qwen/Qwen1.5-MoE-A2.7B; assignment card: 24L
d_model=2048 16H GQA kv=16 d_ff=1408 vocab=151936, MoE 60 experts top-4 +
4 shared experts).

60 routed experts pad to 64 for even expert-parallel sharding over the
16-way model axis (padded experts are masked to -inf in the router).  The 4
shared experts form one dense FFN of 4 x 1408 = 5632 hidden units gated by a
sigmoid (matching the HF reference implementation).
"""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    head_dim=128,
    d_ff=0,                      # all FFN capacity lives in the experts
    vocab=151936,
    mixer="attn",
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408,
                  n_shared=4, d_shared=5632, router_norm_topk=True),
    rope_theta=1000000.0,
    mlp="swiglu",
    tie_embeddings=False,
    max_seq_len=32768,
)
