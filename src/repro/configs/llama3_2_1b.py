"""llama3.2-1b  [dense]  (hf:meta-llama/Llama-3.2-1B; assignment card: 16L
d_model=2048 32H GQA kv=8 d_ff=8192 vocab=128256).
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv=8,
    head_dim=64,
    d_ff=8192,
    vocab=128256,
    mixer="attn",
    rope_theta=500000.0,
    mlp="swiglu",
    tie_embeddings=True,
    max_seq_len=131072,
)
