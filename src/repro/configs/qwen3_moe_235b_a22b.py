"""qwen3-moe-235b-a22b  [moe]  (hf:Qwen/Qwen3-235B-A22B family; assignment
card: 94L d_model=4096 64H GQA kv=4 d_ff=1536 vocab=151936, MoE 128 experts
top-8).

128 experts shard exactly 8-per-device over the 16-way model axis.  QK-norm
per qwen3.
"""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    mixer="attn",
    qk_norm=True,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536,
                  router_norm_topk=True),
    rope_theta=1000000.0,
    mlp="swiglu",
    tie_embeddings=False,
    max_seq_len=131072,
)
