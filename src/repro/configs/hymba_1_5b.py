"""hymba-1.5b  [hybrid]  (arXiv:2411.13676; assignment card: 32L
d_model=1600 25H GQA kv=5 d_ff=5504 vocab=32001, ssm_state=16 — parallel
attention + mamba heads).

Every layer runs attention and an SSM head in parallel on the same input and
averages the outputs.  Hymba uses sliding-window attention in all but 3
full-attention layers (first / middle / last) — encoded in the pattern.
"""

from ..models.config import ArchConfig, SSMConfig

_PAT = ["L"] * 32
for _i in (0, 15, 31):
    _PAT[_i] = "G"

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    mixer="hymba",
    layer_pattern="".join(_PAT),
    window=1024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10000.0,
    mlp="swiglu",
    tie_embeddings=True,
    max_seq_len=1 << 20,
)
