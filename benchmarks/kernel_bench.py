"""Kernel-level benchmark: fused PE kernel vs. unfused op-by-op execution.

Wall-clock on this CPU host is NOT the metric that matters (the kernels
target TPU and run here in interpret mode); the *derived* column is the
TPU-relevant statistic: HBM bytes accessed per element, measured by the same
HLO cost analyzer the roofline uses, for the fused XLA lowering vs the
op-by-op chain.  Fusion wins exactly the paper's PE-specialization way —
fewer HBM round trips per applied op.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphir import pattern_from_spec
from repro.graphir.graph import free_in_ports
from repro.kernels import fused_pe_apply
from repro.launch.hlo_cost import analyze

from .common import emit, timeit

PATTERNS = {
    "conv_relu": pattern_from_spec([("mul", (-1, -1)), ("add", (0, -1)),
                                    ("const", ()), ("max", (1, 2))]),
    "harris_resp": pattern_from_spec([("mul", (-1, -1)), ("mul", (-1, -1)),
                                      ("sub", (0, 1)), ("abs", (2,))]),
    "swiglu_core": pattern_from_spec([("sigmoid", (-1,)), ("mul", (0, -1)),
                                      ("mul", (1, -1))]),
}


def _unfused(pattern, *xs):
    """Each op jitted separately = one HBM round-trip per op (baseline PE)."""
    from repro.kernels.pe_fused import _JNP_SEMANTICS
    from repro.graphir.ops import OPS
    free = free_in_ports(pattern)
    port_vals = {fp: x for fp, x in zip(free, xs)}
    vals = {}
    for n in pattern.topo_order():
        op = pattern.nodes[n]
        if op == "const":
            vals[n] = jnp.float32(pattern.attr(n, "value", 0.0))
            continue
        ins = pattern.in_edges(n)
        args = [vals[ins[p]] if p in ins else port_vals[(n, p)]
                for p in range(OPS[op].arity)]
        vals[n] = jax.jit(_JNP_SEMANTICS[op])(*args)   # separate dispatch
    from repro.graphir.graph import sink_nodes
    return vals[sink_nodes(pattern)[0]]


def _fused_jit_bytes(pattern, xs):
    """HLO bytes of the whole-pattern XLA fusion (TPU-style fused PE)."""
    from repro.kernels.pe_fused import _JNP_SEMANTICS
    from repro.graphir.ops import OPS
    free = free_in_ports(pattern)

    def fn(*inputs):
        port_vals = {fp: x for fp, x in zip(free, inputs)}
        vals = {}
        for n in pattern.topo_order():
            op = pattern.nodes[n]
            if op == "const":
                vals[n] = jnp.float32(pattern.attr(n, "value", 0.0))
                continue
            ins = pattern.in_edges(n)
            args = [vals[ins[p]] if p in ins else port_vals[(n, p)]
                    for p in range(OPS[op].arity)]
            vals[n] = _JNP_SEMANTICS[op](*args)
        from repro.graphir.graph import sink_nodes
        return vals[sink_nodes(pattern)[0]]

    hlo = jax.jit(fn).lower(*xs).compile().as_text()
    return analyze(hlo).bytes


def _unfused_bytes(pattern, xs):
    from repro.kernels.pe_fused import _JNP_SEMANTICS
    from repro.graphir.ops import OPS
    free = free_in_ports(pattern)
    total = 0.0
    port_vals = {fp: x for fp, x in zip(free, xs)}
    vals = {}
    for n in pattern.topo_order():
        op = pattern.nodes[n]
        if op == "const":
            vals[n] = jnp.float32(pattern.attr(n, "value", 0.0))
            continue
        ins = pattern.in_edges(n)
        args = [vals[ins[p]] if p in ins else port_vals[(n, p)]
                for p in range(OPS[op].arity)]
        hlo = jax.jit(_JNP_SEMANTICS[op]).lower(*args).compile().as_text()
        total += analyze(hlo).bytes
        vals[n] = _JNP_SEMANTICS[op](*args)
    return total


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for name, pat in PATTERNS.items():
        n_in = len(free_in_ports(pat))
        xs = [jnp.asarray(rng.uniform(-1, 1, (512, 512)), jnp.float32)
              for _ in range(n_in)]
        us_fused, _ = timeit(
            lambda: jax.block_until_ready(
                fused_pe_apply(pat, *xs, interpret=True)), repeats=1)
        us_unf, _ = timeit(
            lambda: jax.block_until_ready(_unfused(pat, *xs)), repeats=1)
        b_fused = _fused_jit_bytes(pat, xs)
        b_unf = _unfused_bytes(pat, xs)
        emit(f"kernel_{name}", us_fused,
             f"hbm_bytes_fused={b_fused/1e6:.1f}MB"
             f";unfused={b_unf/1e6:.1f}MB"
             f";traffic_x={b_unf/max(b_fused,1):.2f}")
        out[name] = b_unf / max(b_fused, 1)
    return out


if __name__ == "__main__":
    run()
