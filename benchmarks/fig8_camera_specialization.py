"""Paper Fig. 8: energy/op and total active-PE area as the PE is
increasingly specialized for camera pipeline (baseline, PE1..PE5)."""

from __future__ import annotations

from repro.apps import image
from repro.core import baseline_datapath, evaluate_mapping, map_application
from repro.explore import ExploreConfig, Explorer

from .common import BENCH_MINING, emit, timeit


def camera_app():
    """The camera pipeline graph — shared with fabric_camera_bench."""
    return image.build_graph("camera")


def run() -> dict:
    g = camera_app()
    base = baseline_datapath()
    c0 = evaluate_mapping(base, map_application(base, g, "camera"),
                          "baseline")

    cfg = ExploreConfig(mode="per_app", mining=BENCH_MINING, max_merge=4)
    us, res = timeit(
        lambda: Explorer({"camera": g}, cfg).run().results["camera"],
        repeats=1)
    rows = {"baseline": c0}
    for v in res.variants:
        rows[v.name] = v.costs["camera"]

    best = res.best_variant("camera").costs["camera"]
    e_ratio = c0.energy_per_op_pj / best.energy_per_op_pj
    a_ratio = c0.total_area_um2 / best.total_area_um2
    cg_ratio = c0.cgra_energy_per_op_pj / best.cgra_energy_per_op_pj
    for name, c in rows.items():
        emit(f"fig8_{name}", us,
             f"e/op={c.energy_per_op_pj:.4f}pJ"
             f";area={c.total_area_um2/1e3:.1f}kum2"
             f";cgra_e/op={c.cgra_energy_per_op_pj:.4f}pJ"
             f";fmax={c.fmax_ghz:.2f}GHz;ops/pe={c.ops_per_pe:.2f}")
    emit("fig8_ratio_vs_baseline", us,
         f"energy_x={e_ratio:.2f};area_x={a_ratio:.2f};"
         f"cgra_energy_x={cg_ratio:.2f} (paper: 8.3x energy, 3.4x area)")
    return {"rows": rows, "e_ratio": e_ratio, "a_ratio": a_ratio,
            "cgra_ratio": cg_ratio}


if __name__ == "__main__":
    run()
