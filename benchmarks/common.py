"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable, Tuple

from repro.core import MiningConfig

#: mining budget used by all paper-figure benchmarks (keeps the full suite
#: under ~10 min on one CPU core; raise for deeper results)
BENCH_MINING = MiningConfig(min_support=4, max_pattern_nodes=8,
                            time_budget_s=45, max_patterns_per_level=60)

FAST_MINING = MiningConfig(min_support=3, max_pattern_nodes=6,
                           time_budget_s=15, max_patterns_per_level=40)


def timeit(fn: Callable, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    """(best microseconds per call, last result)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = (time.perf_counter() - t0) * 1e6
        best = min(best, dt)
    return best, out


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
