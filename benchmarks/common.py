"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable, Tuple

from repro.core import MiningConfig

#: mining budget used by all paper-figure benchmarks (keeps the full suite
#: under ~10 min on one CPU core; raise for deeper results)
BENCH_MINING = MiningConfig(min_support=4, max_pattern_nodes=8,
                            time_budget_s=45, max_patterns_per_level=60)

FAST_MINING = MiningConfig(min_support=3, max_pattern_nodes=6,
                           time_budget_s=15, max_patterns_per_level=40)


def timeit(fn: Callable, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    """(best microseconds per call, last result)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = (time.perf_counter() - t0) * 1e6
        best = min(best, dt)
    return best, out


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def write_appcost_jsonl(variants_by_app, out_path: str) -> list:
    """Dump AppCost records as jsonl for ``results/make_tables.py … fabric``.

    variants_by_app: iterable of (app_name, variants); every
    ``variant.costs[app_name]`` becomes one row.  Returns the rows.
    """
    import dataclasses
    import json
    import os

    rows = []
    for app_name, variants in variants_by_app:
        for v in variants:
            rows.append(dataclasses.asdict(v.costs[app_name]))
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    return rows
