"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

from repro.core import MiningConfig
from repro.obs.diff import summarize_repeats
from repro.obs.manifest import capture as capture_manifest

#: mining budget used by all paper-figure benchmarks (keeps the full suite
#: under ~10 min on one CPU core; raise for deeper results)
BENCH_MINING = MiningConfig(min_support=4, max_pattern_nodes=8,
                            time_budget_s=45, max_patterns_per_level=60)

FAST_MINING = MiningConfig(min_support=3, max_pattern_nodes=6,
                           time_budget_s=15, max_patterns_per_level=40)


def timeit(fn: Callable, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    """(best microseconds per call, last result)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = (time.perf_counter() - t0) * 1e6
        best = min(best, dt)
    return best, out


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def manifest_block() -> dict:
    """The run-manifest dict every BENCH_*.json embeds (re-inspected per
    call so the xla_cache cold/warm state is current, not import-time)."""
    return capture_manifest(refresh=True).to_dict()


def repeat_timed(fn: Callable[[], object],
                 repeats: int) -> Tuple[List[float], object]:
    """Run ``fn`` ``repeats`` times; (wall-second samples, last result)."""
    samples: List[float] = []
    out = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn()
        samples.append(time.perf_counter() - t0)
    return samples, out


def repeats_block(samples_by_key: Dict[str, List[float]],
                  n: int) -> dict:
    """The ``repeats`` block of a BENCH json: per timed metric, the
    median/IQR summary of its samples — artifacts carry a distribution,
    never a lone wall-clock (see ``repro.obs.diff.summarize_repeats``)."""
    block = {"n": int(n)}
    for key, samples in samples_by_key.items():
        block[key] = summarize_repeats(samples)
    return block


def write_records_jsonl(result, out_path: str) -> list:
    """Dump an :class:`repro.explore.ExploreResult` as schema-versioned
    jsonl (consumable by ``results/make_tables.py … fabric``).

    Returns the row dicts.  The ad-hoc AppCost plumbing this replaces
    lives on as the AppCost column subset of every
    :class:`repro.explore.ExploreRecord`.
    """
    from repro.explore import to_jsonl

    records = result.records()
    to_jsonl(records, out_path)
    return [r.to_dict() for r in records]
