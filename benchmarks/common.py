"""Shared benchmark utilities."""

from __future__ import annotations

import time
from typing import Callable, Tuple

from repro.core import MiningConfig

#: mining budget used by all paper-figure benchmarks (keeps the full suite
#: under ~10 min on one CPU core; raise for deeper results)
BENCH_MINING = MiningConfig(min_support=4, max_pattern_nodes=8,
                            time_budget_s=45, max_patterns_per_level=60)

FAST_MINING = MiningConfig(min_support=3, max_pattern_nodes=6,
                           time_budget_s=15, max_patterns_per_level=40)


def timeit(fn: Callable, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    """(best microseconds per call, last result)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = (time.perf_counter() - t0) * 1e6
        best = min(best, dt)
    return best, out


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def write_records_jsonl(result, out_path: str) -> list:
    """Dump an :class:`repro.explore.ExploreResult` as schema-versioned
    jsonl (consumable by ``results/make_tables.py … fabric``).

    Returns the row dicts.  The ad-hoc AppCost plumbing this replaces
    lives on as the AppCost column subset of every
    :class:`repro.explore.ExploreRecord`.
    """
    from repro.explore import to_jsonl

    records = result.records()
    to_jsonl(records, out_path)
    return [r.to_dict() for r in records]
