"""Fabric PnR benchmark: JAX-batched annealing vs the single-chain Python
placer, plus router and HPWL-kernel microbenchmarks.

The headline comparison holds total annealing work fixed — C chains x S
sweeps — and times (a) the Python reference run chain-by-chain and (b) the
JAX engine running all chains in lockstep; at >= 32 chains the batched
path must win (acceptance criterion).  ``us_per_call`` is microseconds per
*chain*.

Run:  PYTHONPATH=src python -m benchmarks.pnr_bench
"""

from __future__ import annotations

import time

import numpy as np

from repro.apps import image_graphs
from repro.core import baseline_datapath, map_application
from repro.core.dse import app_ops
from repro.fabric import FabricSpec, extract_netlist, lower, place, route_nets
from repro.fabric.place import anneal_jax, anneal_python

from .common import emit

SWEEPS = 24
CHAIN_COUNTS = (1, 8, 32)


def _problem():
    app = image_graphs()["harris"]
    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, "harris")
    spec = FabricSpec(rows=8, cols=8)
    netlist = extract_netlist(mapping, app, spec)
    return dp, mapping, app, spec, netlist


def run() -> None:
    dp, mapping, app, spec, netlist = _problem()
    problem = lower(netlist, spec)

    # -- python single-chain reference, run `chains` times sequentially ----
    py_us = {}
    for chains in CHAIN_COUNTS:
        t0 = time.perf_counter()
        costs = [anneal_python(problem, seed=c, sweeps=SWEEPS)[1]
                 for c in range(chains)]
        dt = (time.perf_counter() - t0) * 1e6
        py_us[chains] = dt / chains
        emit(f"pnr_anneal_python_c{chains}", dt / chains,
             f"best_hpwl={min(costs):.0f}")

    # -- jax batched chains (first call includes trace+compile; report the
    # steady-state second call, which is what a DSE sweep pays) ------------
    jax_us = {}
    for chains in CHAIN_COUNTS:
        anneal_jax(problem, chains=chains, seed=0, sweeps=SWEEPS)  # warmup
        t0 = time.perf_counter()
        _, costs = anneal_jax(problem, chains=chains, seed=1, sweeps=SWEEPS)
        dt = (time.perf_counter() - t0) * 1e6
        jax_us[chains] = dt / chains
        emit(f"pnr_anneal_jax_c{chains}", dt / chains,
             f"best_hpwl={float(np.min(costs)):.0f}")

    for chains in CHAIN_COUNTS:
        emit(f"pnr_jax_speedup_c{chains}", jax_us[chains],
             f"python/jax={py_us[chains] / jax_us[chains]:.2f}x")

    # -- router ------------------------------------------------------------
    placement = place(netlist, spec, backend="jax", chains=8, sweeps=SWEEPS)
    t0 = time.perf_counter()
    routes = route_nets(netlist, placement, spec)
    dt = (time.perf_counter() - t0) * 1e6
    emit("pnr_route_harris", dt,
         f"wl={routes.wirelength};overflow={routes.overflow}")

    # -- HPWL kernel microbenchmark ---------------------------------------
    from repro.kernels.pnr_cost import hpwl_batched

    rng = np.random.default_rng(0)
    n_ent = problem.n_entities
    pos = problem.slot_xy[
        np.stack([rng.permutation(n_ent) for _ in range(256)])]
    pins = problem.net_pins
    mask = problem.net_mask
    hpwl_batched(pos, pins, mask).block_until_ready()      # warmup
    t0 = time.perf_counter()
    hpwl_batched(pos, pins, mask).block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    emit("pnr_hpwl_batched_256", dt, f"nets={pins.shape[0]}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
