"""Fabric PnR benchmark: placer scaling sweep (delta vs full move scoring),
the JAX-batched vs Python-chain comparison, and router/HPWL microbenches.

The headline table anneals synthetic netlists that fill 8x8 .. 64x64
fabrics with both ``score_mode="delta"`` (incremental rescoring of only
the nets a swap touches) and ``score_mode="full"`` (recompute all N nets
per move), verifies the two modes return bit-identical placements, and
reports the per-sweep speedup — the number that bounds how much design
space the DSE loop can sweep.  Each timed anneal is re-run ``--repeats N``
times (default 3 at full budget, 1 in smoke) and the report carries the
median plus a median/IQR ``repeats`` sub-block per size — never a lone
wall-clock.  Results land in machine-readable ``results/BENCH_pnr.json``
(schema ``pnr_bench/v2``, with an embedded run manifest) so the perf
trajectory is tracked across PRs by ``python -m repro.obs.regress``;
acceptance floor is a >=5x speedup at 32x32 plus a completed 64x64 anneal.

The ``hier`` section times the two-level hierarchical flow
(:func:`repro.fabric.place.place_hierarchical`) against the flat anneal
on locality-structured mega-fabric netlists (64x64 and 128x128; 256x256
with ``--mega``, the nightly budget), asserts delta-vs-full bit-identity
at *every level* (cluster, detail, deblock), and records the
hierarchical-vs-flat wall-clock ratio — the number that opens the
>=128x128 regime the flat annealer cannot reach.

Run:  PYTHONPATH=src python -m benchmarks.pnr_bench \
          [--smoke] [--mega] [--repeats N] [--out P]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import numpy as np

from repro.apps import image_graphs
from repro.core import baseline_datapath, map_application
from repro.core.dse import app_ops
from repro.fabric import (FabricSpec, extract_netlist, lower, place,
                          place_hierarchical, route_nets, synthetic_netlist)
from repro.fabric.place import anneal_jax, anneal_python

from .common import emit, manifest_block, repeats_block

DEFAULT_OUT = os.path.join("results", "BENCH_pnr.json")
SWEEPS = 24
CHAIN_COUNTS = (1, 8, 32)
SCALE_SIZES = (8, 16, 32, 64)
#: timing budget for the scaling sweep — per-sweep cost is what a DSE
#: evaluation pays, so a short fixed budget at a fixed seed is enough
SCALE_SWEEPS = 2
SCALE_CHAINS = 1
#: hierarchical sweep: sizes the committed report carries; 256x256 is the
#: nightly (--mega) budget, flat comparison stops at HIER_FLAT_MAX
HIER_SIZES = (64, 128)
HIER_MEGA_SIZE = 256
HIER_FLAT_MAX = 128
#: sink-window radius for the synthetic mega netlists — real mapped
#: dataflow is local; without it there are no clusters to find
HIER_LOCALITY = 4


def _timed_anneal(problem, score_mode: str, *, chains: int, sweeps: int,
                  seed: int, repeats: int = 1):
    """(wall-second samples, slots, costs) for steady-state annealer calls.

    Each repeat re-runs the already-compiled program on the same seed, so
    the samples measure dispatch+run noise while slots/costs stay
    bit-identical across repeats.
    """
    anneal_jax(problem, chains=chains, seed=seed, sweeps=sweeps,
               score_mode=score_mode)                   # trace + compile
    samples = []
    slots = costs = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        slots, costs = anneal_jax(problem, chains=chains, seed=seed + 1,
                                  sweeps=sweeps, score_mode=score_mode)
        samples.append(time.perf_counter() - t0)
    return samples, slots, costs


def scaling_sweep(sizes=SCALE_SIZES, *, sweeps: int = SCALE_SWEEPS,
                  chains: int = SCALE_CHAINS, seed: int = 4,
                  repeats: int = 1) -> list:
    """Anneal synthetic netlists at each size in both score modes."""
    records = []
    for size in sizes:
        spec = FabricSpec(rows=size, cols=size)
        problem = lower(synthetic_netlist(spec, seed=seed), spec)
        rec = {"rows": size, "cols": size,
               "n_cells": problem.n_pe_cells + problem.n_io_cells,
               "n_nets": int(np.count_nonzero(
                   problem.net_mask.any(axis=1))),
               "sweeps": sweeps, "chains": chains}
        s_d, slots_d, costs_d = _timed_anneal(
            problem, "delta", chains=chains, sweeps=sweeps, seed=seed,
            repeats=repeats)
        s_f, slots_f, costs_f = _timed_anneal(
            problem, "full", chains=chains, sweeps=sweeps, seed=seed,
            repeats=repeats)
        dt_d = statistics.median(s_d)
        dt_f = statistics.median(s_f)
        rec["delta_wall_s"] = dt_d
        rec["full_wall_s"] = dt_f
        rec["delta_us_per_sweep"] = dt_d * 1e6 / sweeps
        rec["full_us_per_sweep"] = dt_f * 1e6 / sweeps
        rec["speedup"] = dt_f / dt_d
        rec["repeats"] = repeats_block(
            {"delta_wall_s": s_d, "full_wall_s": s_f}, repeats)
        rec["delta_hpwl"] = float(np.min(costs_d))
        rec["full_hpwl"] = float(np.min(costs_f))
        rec["bit_identical"] = bool(np.array_equal(slots_d, slots_f)
                                    and np.array_equal(costs_d, costs_f))
        # the smoke step's whole point: a delta/full divergence must fail
        # the run (and CI), not just record False in the report
        assert rec["bit_identical"], (
            f"score_mode divergence at {size}x{size}: delta returned "
            f"hpwl={rec['delta_hpwl']}, full {rec['full_hpwl']}")
        records.append(rec)
        emit(f"pnr_scale_{size}x{size}_delta", dt_d * 1e6 / sweeps,
             f"hpwl={rec['delta_hpwl']:.0f};cells={rec['n_cells']}")
        emit(f"pnr_scale_{size}x{size}_full", dt_f * 1e6 / sweeps,
             f"hpwl={rec['full_hpwl']:.0f};"
             f"speedup={rec['speedup']:.2f}x;"
             f"identical={rec['bit_identical']}")
    return records


def anneal_64x64(*, chains: int = 2, sweeps: int = 8, seed: int = 4,
                 repeats: int = 1) -> dict:
    """A realistic-budget 64x64 anneal — only feasible with delta scoring;
    records the completed run the ROADMAP scaling item asks for."""
    spec = FabricSpec(rows=64, cols=64)
    problem = lower(synthetic_netlist(spec, seed=seed), spec)
    t0 = time.perf_counter()
    anneal_jax(problem, chains=chains, seed=seed, sweeps=sweeps,
               score_mode="delta")                      # trace + compile
    compile_s = time.perf_counter() - t0
    samples = []
    costs = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        _, costs = anneal_jax(problem, chains=chains, seed=seed + 1,
                              sweeps=sweeps, score_mode="delta")
        samples.append(time.perf_counter() - t0)
    wall = statistics.median(samples)
    rec = {"rows": 64, "cols": 64, "chains": chains, "sweeps": sweeps,
           "score_mode": "delta", "wall_s": wall,
           "compile_and_first_run_s": compile_s,
           "repeats": repeats_block({"wall_s": samples}, repeats),
           "n_cells": problem.n_pe_cells + problem.n_io_cells,
           "best_hpwl": float(np.min(costs)), "completed": True}
    emit("pnr_anneal_64x64_delta", wall * 1e6,
         f"best_hpwl={rec['best_hpwl']:.0f};sweeps={sweeps}x{chains}ch")
    return rec


def _hier_levels_identical(a, b) -> dict:
    """Per-level delta-vs-full comparison of two HierPlacements."""
    return {
        "cluster": bool(np.array_equal(a.cluster_slots, b.cluster_slots)),
        "detail": bool(set(a.detail_slots) == set(b.detail_slots)
                       and all(np.array_equal(a.detail_slots[k],
                                              b.detail_slots[k])
                               for k in a.detail_slots)),
        "deblock": bool((a.deblock_slots is None) == (b.deblock_slots is None)
                        and (a.deblock_slots is None
                             or np.array_equal(a.deblock_slots,
                                               b.deblock_slots))),
        "final": bool(a.coords == b.coords and a.cost == b.cost),
    }


def hier_sweep(sizes=HIER_SIZES, *, chains: int = 2, sweeps: int = 2,
               seed: int = 4, repeats: int = 1,
               flat_max: int = HIER_FLAT_MAX) -> list:
    """Time hierarchical vs flat placement on locality-structured
    netlists; assert per-level delta/full bit-identity at every size."""
    records = []
    for size in sizes:
        spec = FabricSpec(rows=size, cols=size)
        nl = synthetic_netlist(spec, seed=seed, locality=HIER_LOCALITY)

        def hier(score_mode):
            return place_hierarchical(nl, spec, chains=chains,
                                      sweeps=sweeps, seed=seed + 1,
                                      score_mode=score_mode)

        # bit-identity first — these runs also compile both programs
        hd, hf = hier("delta"), hier("full")
        levels = _hier_levels_identical(hd, hf)
        assert all(levels.values()), (
            f"hierarchical score_mode divergence at {size}x{size}: "
            f"{levels}")
        rec = {"rows": size, "cols": size, "chains": chains,
               "sweeps": sweeps, "cluster_grid": hd.cluster_grid,
               "n_cells": len(nl.pe_cells) + len(nl.io_cells),
               "n_nets": len(nl.nets),
               "detail_dispatches": hd.detail_dispatches,
               "hier_hpwl": hd.cost,
               "bit_identical_levels": levels}
        s_h = []
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            hier("delta")
            s_h.append(time.perf_counter() - t0)
        rec["hier_wall_s"] = statistics.median(s_h)
        samples = {"hier_wall_s": s_h}
        if size <= flat_max:
            place(nl, spec, backend="jax", chains=chains, sweeps=sweeps,
                  seed=seed + 1, score_mode="delta")      # trace + compile
            s_f = []
            flat_pl = None
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                flat_pl = place(nl, spec, backend="jax", chains=chains,
                                sweeps=sweeps, seed=seed + 1,
                                score_mode="delta")
                s_f.append(time.perf_counter() - t0)
            rec["flat_wall_s"] = statistics.median(s_f)
            rec["flat_hpwl"] = flat_pl.cost
            rec["speedup_vs_flat"] = rec["flat_wall_s"] / rec["hier_wall_s"]
            samples["flat_wall_s"] = s_f
        rec["repeats"] = repeats_block(samples, repeats)
        rec["completed"] = True
        records.append(rec)
        emit(f"pnr_hier_{size}x{size}", rec["hier_wall_s"] * 1e6,
             f"hpwl={hd.cost:.0f};grid={hd.cluster_grid};"
             + (f"vs_flat={rec['speedup_vs_flat']:.2f}x"
                if "speedup_vs_flat" in rec else "flat=skipped"))
    return records


def hier_cluster1_check(size: int = 32, *, chains: int = 2,
                        sweeps: int = 2, seed: int = 4) -> dict:
    """cluster_grid=1 must reproduce the flat placer bit-for-bit."""
    spec = FabricSpec(rows=size, cols=size)
    nl = synthetic_netlist(spec, seed=seed, locality=HIER_LOCALITY)
    flat = place(nl, spec, backend="jax", chains=chains, sweeps=sweeps,
                 seed=seed, score_mode="delta")
    h1 = place_hierarchical(nl, spec, cluster_grid=1, chains=chains,
                            sweeps=sweeps, seed=seed, score_mode="delta")
    identical = bool(h1.coords == flat.coords and h1.cost == flat.cost
                     and h1.chain_costs == flat.chain_costs)
    assert identical, (
        f"cluster_grid=1 diverged from flat at {size}x{size}: "
        f"{h1.cost} vs {flat.cost}")
    emit(f"pnr_hier_cluster1_{size}x{size}", 0.0,
         f"identical={identical}")
    return {"rows": size, "cols": size, "cluster1_identical": identical}


def _harris_problem():
    app = image_graphs()["harris"]
    dp = baseline_datapath(app_ops(app))
    mapping = map_application(dp, app, "harris")
    spec = FabricSpec(rows=8, cols=8)
    netlist = extract_netlist(mapping, app, spec)
    return dp, mapping, app, spec, netlist


def harris_bench() -> dict:
    """The original harris-app comparison: python chains vs batched JAX,
    router timing, and the batched-HPWL microkernel."""
    dp, mapping, app, spec, netlist = _harris_problem()
    problem = lower(netlist, spec)
    out = {"python_us_per_chain": {}, "jax_us_per_chain": {}}

    # -- python single-chain reference, run `chains` times sequentially ----
    for chains in CHAIN_COUNTS:
        t0 = time.perf_counter()
        costs = [anneal_python(problem, seed=c, sweeps=SWEEPS)[1]
                 for c in range(chains)]
        dt = (time.perf_counter() - t0) * 1e6
        out["python_us_per_chain"][chains] = dt / chains
        emit(f"pnr_anneal_python_c{chains}", dt / chains,
             f"best_hpwl={min(costs):.0f}")

    # -- jax batched chains (first call includes trace+compile; report the
    # steady-state second call, which is what a DSE sweep pays) ------------
    for chains in CHAIN_COUNTS:
        anneal_jax(problem, chains=chains, seed=0, sweeps=SWEEPS)  # warmup
        t0 = time.perf_counter()
        _, costs = anneal_jax(problem, chains=chains, seed=1, sweeps=SWEEPS)
        dt = (time.perf_counter() - t0) * 1e6
        out["jax_us_per_chain"][chains] = dt / chains
        emit(f"pnr_anneal_jax_c{chains}", dt / chains,
             f"best_hpwl={float(np.min(costs)):.0f}")

    for chains in CHAIN_COUNTS:
        emit(f"pnr_jax_speedup_c{chains}", out["jax_us_per_chain"][chains],
             f"python/jax={out['python_us_per_chain'][chains] / out['jax_us_per_chain'][chains]:.2f}x")

    # -- router ------------------------------------------------------------
    placement = place(netlist, spec, backend="jax", chains=8, sweeps=SWEEPS)
    t0 = time.perf_counter()
    routes = route_nets(netlist, placement, spec)
    dt = (time.perf_counter() - t0) * 1e6
    out["route_us"] = dt
    emit("pnr_route_harris", dt,
         f"wl={routes.wirelength};overflow={routes.overflow}")

    # -- HPWL kernel microbenchmark ---------------------------------------
    from repro.kernels.pnr_cost import hpwl_batched

    rng = np.random.default_rng(0)
    n_ent = problem.n_entities
    pos = problem.slot_xy[
        np.stack([rng.permutation(n_ent) for _ in range(256)])]
    pins = problem.net_pins
    mask = problem.net_mask
    hpwl_batched(pos, pins, mask).block_until_ready()      # warmup
    t0 = time.perf_counter()
    hpwl_batched(pos, pins, mask).block_until_ready()
    dt = (time.perf_counter() - t0) * 1e6
    out["hpwl_batched_256_us"] = dt
    emit("pnr_hpwl_batched_256", dt, f"nets={pins.shape[0]}")
    return out


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats=None, mega: bool = False) -> dict:
    import jax

    if repeats is None:
        repeats = 1 if smoke else 3
    repeats = max(1, int(repeats))
    report = {"schema": "pnr_bench/v3",
              "host_backend": jax.default_backend(),
              "smoke": smoke,
              "manifest": manifest_block(),
              "repeats": {"n": repeats}}
    if smoke:
        # CI smoke: 8x8, 2 sweeps, both score modes — proves the delta and
        # full programs still agree and keeps a perf datapoint per PR;
        # plus one tiny hierarchical placement with its level-identity and
        # cluster_grid=1 == flat gates
        report["sizes"] = scaling_sweep((8,), sweeps=2, repeats=repeats)
        report["hier"] = hier_sweep((32,), repeats=repeats)
        report["hier_cluster1"] = hier_cluster1_check(32)
    else:
        report["sizes"] = scaling_sweep(repeats=repeats)
        report["anneal64"] = anneal_64x64(repeats=repeats)
        report["harris"] = harris_bench()
        sizes = HIER_SIZES + ((HIER_MEGA_SIZE,) if mega else ())
        report["hier"] = hier_sweep(sizes, repeats=repeats)
        report["hier_cluster1"] = hier_cluster1_check(32)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    emit("pnr_bench_json", 0.0, f"path={out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--smoke", action="store_true",
                    help="8x8 only, 2 sweeps, both score modes (CI step)")
    ap.add_argument("--mega", action="store_true",
                    help="add the 256x256 hierarchical placement "
                         "(nightly budget)")
    ap.add_argument("--repeats", type=int, default=None, metavar="N",
                    help="timed repeats per anneal (default: 3 full, "
                         "1 smoke); the report records median + IQR")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out, smoke=args.smoke, repeats=args.repeats, mega=args.mega)


if __name__ == "__main__":
    main()
