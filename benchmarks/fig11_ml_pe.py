"""Paper Fig. 11: normalized energy and area for ML kernels (Conv, Block,
StrC, DS) on PE ML (domain) vs PE Spec (per-kernel) vs baseline."""

from __future__ import annotations

from repro.apps import ml_graphs
from repro.core import baseline_datapath, evaluate_mapping, map_application
from repro.explore import ExploreConfig, Explorer

from .common import BENCH_MINING, emit, timeit


def run() -> dict:
    apps = ml_graphs()
    base = baseline_datapath()
    base_costs = {n: evaluate_mapping(base, map_application(base, g, n),
                                      "baseline") for n, g in apps.items()}
    # shared memo store: the per-kernel sweep reuses the PE ML run's mining
    ex = Explorer(apps, ExploreConfig(mode="domain", mining=BENCH_MINING,
                                      per_app_subgraphs=2,
                                      domain_name="PE_ML"))
    us_ml, ml = timeit(lambda: ex.run().results["PE_ML"], repeats=1)
    us_sp, per_app = timeit(
        lambda: ex.with_config(mode="per_app", max_merge=3).run().results,
        repeats=1)
    out = {}
    worst_saving = 1.0
    for name in sorted(apps):
        c_base = base_costs[name]
        c_ml = ml.variants[0].costs[name]
        c_sp = per_app[name].best_variant(name).costs[name]
        e_ml = c_ml.energy_per_op_pj / c_base.energy_per_op_pj
        a_ml = c_ml.total_area_um2 / c_base.total_area_um2
        e_sp = c_sp.energy_per_op_pj / c_base.energy_per_op_pj
        worst_saving = min(worst_saving, e_ml)
        emit(f"fig11_{name}", us_ml + us_sp,
             f"PE_ML:e={e_ml:.3f},a={a_ml:.3f};PE_Spec:e={e_sp:.3f} "
             f"(paper: PE ML up to 60.15% lower energy)")
        out[name] = {"ml": (e_ml, a_ml), "spec": e_sp}
    emit("fig11_best_ml_energy_saving", us_ml,
         f"{(1-worst_saving)*100:.1f}% (paper: up to 60.15%)")
    return out


if __name__ == "__main__":
    run()
