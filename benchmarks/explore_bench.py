"""Batched-vs-serial stage benchmarks on the Fig. 11 ML suite.

Default mode — the pnr stage: the pre-``repro.explore`` driver placed
every (variant, app) pair in its own annealing call: one jit compile per
problem shape plus one device dispatch per pair.  The Explorer's ``pnr``
stage gathers all pairs, pads them to bucket shapes, and anneals every
bucket-compatible group's chains in ONE JAX dispatch — so a whole
exploration pays a couple of compiles instead of one per pair.

``--simulate`` — the schedule/simulate stages: the per-pair loop runs the
modulo scheduler one pair at a time in Python and compiles one
``lax.scan`` per program; the batch-first stages advance all pairs'
schedulers in lockstep (stacked slot-conflict scans) and run every
bucket-compatible group of programs through ONE vmapped scan
(``sim_batch="grouped"``), with bit-identical schedules and outputs.

Both modes run from a shared upstream store (everything upstream of the
stage under test is already done) and from cold compile caches (a fresh
exploration's real cost).  ``--repeats N`` (default 3 at full budget, 1
in smoke) re-runs each timed stage N times — the memo store is purged
between repeats via ``Explorer.forget`` — and the artifact records the
**median** wall-clock plus a median/IQR ``repeats`` block, never a lone
sample.  Every artifact also embeds a run ``manifest`` (git SHA,
versions, device, XLA-cache state) and memory gauges (per-stage host
peak + live device bytes, measured on a separate untimed telemetry
pass).  Results land in ``results/BENCH_explore.json`` /
``results/BENCH_sim_batch.json`` (committed + CI artifact + gated by
``results/check_bench.py`` and tracked by ``python -m
repro.obs.regress``).

Run:  PYTHONPATH=src python -m benchmarks.explore_bench \
          [--simulate] [--smoke] [--repeats N] [--out P]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

from repro.apps import ml_graphs
from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec

from .common import (BENCH_MINING, FAST_MINING, emit, manifest_block,
                     repeats_block)

DEFAULT_OUT = os.path.join("results", "BENCH_explore.json")
DEFAULT_SIM_OUT = os.path.join("results", "BENCH_sim_batch.json")


def _write(result: dict, out_path: str) -> None:
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def _default_repeats(smoke: bool, repeats) -> int:
    """Nightly/full runs default to median-of-3; smoke stays single-shot
    (its assertions are ratios, and CI minutes are budgeted)."""
    if repeats is not None:
        return max(1, int(repeats))
    return 1 if smoke else 3


def _counter_snapshot(registry) -> dict:
    return dict(registry.to_dict()["counters"])


def _metrics_block(registry, before: dict, keys) -> dict:
    """Registry counter deltas for the BENCH json ``metrics`` block.

    Keys must stay inside ``results/check_bench.py``'s METRIC_KEYS
    contract; dotted counter families (``memo.hit.*`` -> ``memo_hit``)
    are summed.  The gate cross-checks the dispatch entries against the
    top-level claims, so these numbers are the registry speaking, not a
    hand-maintained copy.
    """
    after = _counter_snapshot(registry)
    families = {"memo_hit": "memo.hit", "memo_miss": "memo.miss",
                "compile_events": "jax.compile.events"}
    block = {}
    for key in keys:
        prefix = families.get(key, key)
        block[key] = sum(v - before.get(k, 0) for k, v in after.items()
                         if k == prefix or k.startswith(prefix + "."))
    return block


def _memory_gauges(registry, stages) -> dict:
    """Max per-stage host-peak / device-byte gauges (set by the untimed
    telemetry pass) in METRIC_KEYS shape."""
    gauges = registry.to_dict()["gauges"]

    def peak(prefix):
        vals = [v for k, v in gauges.items()
                if k.startswith(prefix) and k.split(".")[-1] in stages
                and isinstance(v, (int, float))]
        return int(max(vals)) if vals else 0

    return {"host_peak_bytes": peak("mem.host_peak_bytes."),
            "device_bytes": peak("mem.device_bytes.")}


def _memory_pass(base, stages, run_fn) -> None:
    """One untimed instrumented run: telemetry on (tracemalloc spans +
    device-byte gauges), compile caches warm from the timed repeats, so
    this measures footprint without polluting the wall-clock samples."""
    from repro import obs
    obs.enable_telemetry()
    try:
        base.forget(*stages)
        run_fn()
    finally:
        obs.enable_telemetry(False)


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats=None) -> dict:
    repeats = _default_repeats(smoke, repeats)
    apps = ml_graphs()
    fabric = FabricOptions(
        spec=FabricSpec(rows=16, cols=16), backend="jax",
        chains=4 if smoke else 8, sweeps=8 if smoke else 24)
    cfg = ExploreConfig(mode="per_app",
                        mining=FAST_MINING if smoke else BENCH_MINING,
                        max_merge=2 if smoke else 3, fabric=fabric)

    # shared upstream artifacts: both modes see identical mappings
    base = Explorer(apps, cfg)
    base.map()
    from repro.obs import jaxprof
    jaxprof.enable(registry=base.metrics)

    def timed_pnr(pnr_batch: str):
        # fresh annealer programs + a purged pnr memo per repeat (cold
        # caches emulate a fresh exploration); the memo store is shared
        # for the upstream stages but pnr keys include pnr_batch, so each
        # mode places from scratch
        import importlib
        # repro.fabric re-exports the place() *function*, shadowing the
        # submodule attribute — resolve the module explicitly
        place_mod = importlib.import_module("repro.fabric.place")
        place_mod._build_annealer.cache_clear()
        place_mod._build_batch_annealer.cache_clear()
        base.forget("pnr")
        ex = base.with_config(pnr_batch=pnr_batch)
        before = ex.stats["pnr_dispatch"]     # the stats Counter is shared
        t0 = time.perf_counter()
        pnrs = ex.pnr()
        dt = time.perf_counter() - t0
        failures.extend(ex.failures)          # clean-run proof: see the
        return dt, pnrs, ex.stats["pnr_dispatch"] - before   # failures block

    samples = {"serial_s": [], "grouped_s": []}
    failures: list = []
    serial_pnrs = serial_disp = None
    for _ in range(repeats):
        dt, serial_pnrs, serial_disp = timed_pnr("serial")
        samples["serial_s"].append(dt)
    grouped_pnrs = grouped_disp = None
    before = None
    for _ in range(repeats):
        before = _counter_snapshot(base.metrics)   # last repeat's deltas
        dt, grouped_pnrs, grouped_disp = timed_pnr("grouped")
        samples["grouped_s"].append(dt)
    metrics = _metrics_block(base.metrics, before,
                             ("pnr_dispatch", "memo_miss", "memo_hit",
                              "compile_events"))
    _memory_pass(base, ("pnr",),
                 lambda: base.with_config(pnr_batch="grouped").pnr())
    metrics.update(_memory_gauges(base.metrics, ("pnr",)))
    jaxprof.disable()

    pairs = len(serial_pnrs)
    assert len(grouped_pnrs) == pairs
    # both modes must produce equally valid arrays: every net routed on a
    # legally fitted grid
    for pnrs in (serial_pnrs, grouped_pnrs):
        for pnr in pnrs.values():
            assert pnr.routes.success, "routing overflow in benchmark run"

    serial_s = statistics.median(samples["serial_s"])
    grouped_s = statistics.median(samples["grouped_s"])
    speedup = serial_s / max(grouped_s, 1e-9)
    result = {
        "bench": "explore_pnr_batch",
        "suite": "fig11_ml@16x16",
        "mode": "smoke" if smoke else "full",
        "manifest": manifest_block(),
        "pairs": pairs,
        "chains": fabric.chains,
        "sweeps": fabric.sweeps,
        "serial_dispatches": serial_disp,
        "grouped_dispatches": grouped_disp,
        "serial_s": round(serial_s, 3),
        "grouped_s": round(grouped_s, 3),
        "speedup": round(speedup, 2),
        "repeats": repeats_block(samples, repeats),
        # registry deltas for the grouped run — check_bench.py asserts
        # pnr_dispatch agrees with grouped_dispatches above
        "metrics": metrics,
        # check_bench.py rejects artifacts measured on degraded runs
        "failures": [f.to_dict() for f in failures],
        "note": "pnr stage only, shared upstream artifacts, cold annealer "
                "caches per repeat (includes jit compiles — the cost of a "
                "fresh exploration); wall-clocks are medians over repeats",
    }
    _write(result, out_path)

    emit("explore_pnr_serial", serial_s * 1e6,
         f"pairs={pairs};dispatches={result['serial_dispatches']}")
    emit("explore_pnr_grouped", grouped_s * 1e6,
         f"pairs={pairs};dispatches={result['grouped_dispatches']}")
    emit("explore_pnr_speedup", grouped_s * 1e6,
         f"{speedup:.2f}x (target >=3x);repeats={repeats};out={out_path}")
    if smoke:
        assert speedup > 1.0, (
            f"batched pnr slower than serial ({speedup:.2f}x)")
    return result


def run_sim(out_path: str = DEFAULT_SIM_OUT, smoke: bool = False,
            repeats=None) -> dict:
    """Schedule+simulate stages, serial vs grouped, from shared pnr."""
    import numpy as np

    from repro.explore.pipeline import _pair_nonce
    from repro.sim import random_inputs, sim_signature, simulate, \
        simulate_batch
    from repro.sim import cycle as cycle_mod

    repeats = _default_repeats(smoke, repeats)
    apps = ml_graphs()
    fabric = FabricOptions(
        spec=FabricSpec(rows=16, cols=16), backend="jax",
        chains=4 if smoke else 8, sweeps=8 if smoke else 24, simulate=True)
    cfg = ExploreConfig(mode="per_app",
                        mining=FAST_MINING if smoke else BENCH_MINING,
                        max_merge=2 if smoke else 3, fabric=fabric)

    # shared upstream artifacts: both modes schedule the same placements
    base = Explorer(apps, cfg)
    base.pnr()
    from repro.obs import jaxprof
    jaxprof.enable(registry=base.metrics)

    def timed(sim_batch: str):
        # cold compile caches + purged sched/sim memo per repeat emulate
        # a fresh exploration; the sched/sim memo keys include sim_batch,
        # so each mode works from scratch
        cycle_mod._build_batch_stepper.cache_clear()
        base.forget("sched", "sim")
        ex = base.with_config(sim_batch=sim_batch)
        d0 = {k: ex.stats[k] for k in ("sim_dispatch", "sched_group")}
        t0 = time.perf_counter()
        progs = ex.schedule()
        flags = ex.simulate()
        dt = time.perf_counter() - t0
        failures.extend(ex.failures)          # clean-run proof
        return dt, progs, flags, {k: ex.stats[k] - d0[k] for k in d0}

    samples = {"serial_s": [], "grouped_s": []}
    failures: list = []
    serial_progs = serial_flags = None
    for _ in range(repeats):
        dt, serial_progs, serial_flags, _d = timed("serial")
        samples["serial_s"].append(dt)
    grouped_progs = grouped_flags = disp = None
    before = None
    for _ in range(repeats):
        before = _counter_snapshot(base.metrics)   # last repeat's deltas
        dt, grouped_progs, grouped_flags, disp = timed("grouped")
        samples["grouped_s"].append(dt)
    metrics_blk = _metrics_block(
        base.metrics, before,
        ("sim_dispatch", "sched_group", "sched_rounds", "sched_backtracks",
         "memo_miss", "memo_hit", "compile_events"))

    def sim_pass():
        ex = base.with_config(sim_batch="grouped")
        ex.schedule()
        ex.simulate()

    _memory_pass(base, ("sched", "sim"), sim_pass)
    metrics_blk.update(_memory_gauges(base.metrics,
                                      ("schedule", "simulate")))
    jaxprof.disable()

    pairs = sorted(serial_progs)
    assert sorted(grouped_progs) == pairs
    # both modes bit-exact against the interpreter on the same
    # nonce-seeded vectors (sim_verify raises otherwise) ...
    verified = (all(serial_flags[p] == 1 for p in pairs)
                and all(grouped_flags[p] == 1 for p in pairs))
    # ... and the achieved schedules are identical
    ii_identical = all(serial_progs[p].ii == grouped_progs[p].ii
                       and serial_progs[p].latency == grouped_progs[p].latency
                       for p in pairs)
    # direct bit-compare of the two modes' simulated outputs (the serial
    # steppers and the grouped bucket programs are already compiled, so
    # this re-run is cheap)
    K, B = fabric.sim_iterations, fabric.sim_batch
    inputs = {p: random_inputs(serial_progs[p], K, B,
                               seed=fabric.input_seed(_pair_nonce(*p)))
              for p in pairs}
    by_bucket = {}
    for p in pairs:
        sig = sim_signature(grouped_progs[p], K, B)
        by_bucket.setdefault(sig, []).append(p)
    bit_identical = True
    for members in by_bucket.values():
        batch = simulate_batch([grouped_progs[p] for p in members],
                               [inputs[p] for p in members])
        for p, res in zip(members, batch):
            ref = simulate(serial_progs[p], inputs[p])
            bit_identical &= bool(np.array_equal(res.outputs, ref.outputs))

    serial_s = statistics.median(samples["serial_s"])
    grouped_s = statistics.median(samples["grouped_s"])
    speedup = serial_s / max(grouped_s, 1e-9)
    result = {
        "bench": "explore_sim_batch",
        "suite": "fig11_ml@16x16",
        "mode": "smoke" if smoke else "full",
        "manifest": manifest_block(),
        "pairs": len(pairs),
        "sim_iterations": K,
        "sim_input_batch": B,
        "serial_compiles": len(pairs),
        "grouped_sim_dispatches": disp["sim_dispatch"],
        "grouped_sched_groups": disp["sched_group"],
        "serial_s": round(serial_s, 3),
        "grouped_s": round(grouped_s, 3),
        "speedup": round(speedup, 2),
        "repeats": repeats_block(samples, repeats),
        "bit_identical": bit_identical,
        "ii_identical": ii_identical,
        "verified": verified,
        # registry deltas for the grouped run — check_bench.py asserts the
        # dispatch/group entries agree with the claims above
        "metrics": metrics_blk,
        # check_bench.py rejects artifacts measured on degraded runs
        "failures": [f.to_dict() for f in failures],
        "note": "schedule+simulate stages only, shared pnr artifacts, cold "
                "stepper caches per repeat (includes jit compiles — the "
                "cost of a fresh simulate=True exploration); wall-clocks "
                "are medians over repeats",
    }
    _write(result, out_path)

    emit("explore_sim_serial", serial_s * 1e6,
         f"pairs={len(pairs)};compiles={len(pairs)}")
    emit("explore_sim_grouped", grouped_s * 1e6,
         f"pairs={len(pairs)};dispatches={disp['sim_dispatch']}")
    emit("explore_sim_speedup", grouped_s * 1e6,
         f"{speedup:.2f}x (target >=3x);repeats={repeats};out={out_path}")
    assert bit_identical and ii_identical and verified, \
        "batched schedule/simulate diverged from the per-pair path"
    if smoke:
        assert speedup > 1.0, (
            f"batched simulate slower than serial ({speedup:.2f}x)")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument("--simulate", action="store_true",
                    help="benchmark the schedule/simulate stages instead "
                         "of pnr (writes BENCH_sim_batch.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced budget + speedup>1 assertion (CI)")
    ap.add_argument("--repeats", type=int, default=None, metavar="N",
                    help="timed repeats per mode (default: 3 full, "
                         "1 smoke); artifacts record median + IQR")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also write a Chrome trace of the benchmark run "
                         "(open in Perfetto / `python -m repro.obs.report`)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.trace:
        from repro import obs
        obs.enable_tracing()
    try:
        if args.simulate:
            run_sim(args.out or DEFAULT_SIM_OUT, smoke=args.smoke,
                    repeats=args.repeats)
        else:
            run(args.out or DEFAULT_OUT, smoke=args.smoke,
                repeats=args.repeats)
    finally:
        if args.trace:
            tracer = obs.disable_tracing()
            os.makedirs(os.path.dirname(args.trace) or ".", exist_ok=True)
            tracer.write_chrome(args.trace)
            print(f"# trace -> {args.trace}", file=sys.stderr)


if __name__ == "__main__":
    main()
