"""Batched-vs-serial pnr stage benchmark on the Fig. 11 ML suite.

The pre-``repro.explore`` driver placed every (variant, app) pair in its
own annealing call: one jit compile per problem shape plus one device
dispatch per pair.  The Explorer's ``pnr`` stage gathers all pairs, pads
them to bucket shapes, and anneals every bucket-compatible group's chains
in ONE JAX dispatch — so a whole exploration pays a couple of compiles
instead of one per pair.

Both modes run from a shared upstream store (mine/rank/merge/map already
done — this isolates the pnr stage, the claim under test) and from cold
annealer caches (a fresh exploration's real cost).  Results land in
``results/BENCH_explore.json`` (committed + CI artifact).

Run:  PYTHONPATH=src python -m benchmarks.explore_bench [--smoke] [--out P]
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.apps import ml_graphs
from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec

from .common import BENCH_MINING, FAST_MINING, emit

DEFAULT_OUT = os.path.join("results", "BENCH_explore.json")


def run(out_path: str = DEFAULT_OUT, smoke: bool = False) -> dict:
    apps = ml_graphs()
    fabric = FabricOptions(
        spec=FabricSpec(rows=16, cols=16), backend="jax",
        chains=4 if smoke else 8, sweeps=8 if smoke else 24)
    cfg = ExploreConfig(mode="per_app",
                        mining=FAST_MINING if smoke else BENCH_MINING,
                        max_merge=2 if smoke else 3, fabric=fabric)

    # shared upstream artifacts: both modes see identical mappings
    base = Explorer(apps, cfg)
    base.map()

    def timed_pnr(pnr_batch: str):
        # fresh annealer programs per mode (cold caches emulate a fresh
        # exploration); the memo store is shared for the upstream stages
        # but pnr keys include pnr_batch, so each mode places from scratch
        import importlib
        # repro.fabric re-exports the place() *function*, shadowing the
        # submodule attribute — resolve the module explicitly
        place_mod = importlib.import_module("repro.fabric.place")
        place_mod._build_annealer.cache_clear()
        place_mod._build_batch_annealer.cache_clear()
        ex = base.with_config(pnr_batch=pnr_batch)
        before = ex.stats["pnr_dispatch"]     # the stats Counter is shared
        t0 = time.perf_counter()
        pnrs = ex.pnr()
        dt = time.perf_counter() - t0
        return dt, pnrs, ex.stats["pnr_dispatch"] - before

    serial_s, serial_pnrs, serial_disp = timed_pnr("serial")
    grouped_s, grouped_pnrs, grouped_disp = timed_pnr("grouped")

    pairs = len(serial_pnrs)
    assert len(grouped_pnrs) == pairs
    # both modes must produce equally valid arrays: every net routed on a
    # legally fitted grid
    for pnrs in (serial_pnrs, grouped_pnrs):
        for pnr in pnrs.values():
            assert pnr.routes.success, "routing overflow in benchmark run"

    speedup = serial_s / max(grouped_s, 1e-9)
    result = {
        "bench": "explore_pnr_batch",
        "suite": "fig11_ml@16x16",
        "mode": "smoke" if smoke else "full",
        "pairs": pairs,
        "chains": fabric.chains,
        "sweeps": fabric.sweeps,
        "serial_dispatches": serial_disp,
        "grouped_dispatches": grouped_disp,
        "serial_s": round(serial_s, 3),
        "grouped_s": round(grouped_s, 3),
        "speedup": round(speedup, 2),
        "note": "pnr stage only, shared upstream artifacts, cold annealer "
                "caches (includes jit compiles — the cost of a fresh "
                "exploration)",
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")

    emit("explore_pnr_serial", serial_s * 1e6,
         f"pairs={pairs};dispatches={result['serial_dispatches']}")
    emit("explore_pnr_grouped", grouped_s * 1e6,
         f"pairs={pairs};dispatches={result['grouped_dispatches']}")
    emit("explore_pnr_speedup", grouped_s * 1e6,
         f"{speedup:.2f}x (target >=3x);out={out_path}")
    if smoke:
        assert speedup > 1.0, (
            f"batched pnr slower than serial ({speedup:.2f}x)")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced budget + speedup>1 assertion (CI)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
