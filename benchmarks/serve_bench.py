"""Serving-layer benchmark: N concurrent clients vs serial, on one fleet.

Four clients each request an overlapping (rotated) two-app window of the
Fig. 11 ML suite.  Serving them *serially* — each client a fresh
Explorer, the status quo before the service — explores every app as many
times as clients name it.  Serving them *concurrently* through
:class:`repro.serve.ExploreService` coalesces the windows into one
continuous batch: every unique app mined/placed/simulated once, pairs
grouped across requests into shared JAX dispatches.  The artifact
records:

* ``speedup`` — serial wall-clock / batched wall-clock (target >= 2x at
  full budget: each app is named by two clients, so the union run does
  half the work);
* ``dispatch_ratio`` — batched dispatches / a *single* union client's
  dispatches (the acceptance claim: adding 3 more overlapping clients
  must cost < 1.5x one client's dispatch count — ideally 1.0x);
* ``bit_identical`` — every client's served records (batched AND the
  cache-hit resubmission) byte-equal its solo Explorer run's records;
* ``cache_hit_ms`` / ``cache_speedup`` — repeat-request latency from
  the response cache vs the per-request cost of actually exploring.

Medians over ``--repeats`` (fresh stores per repeat; jit caches warm
after the first, identically for both modes).  Results land in
``results/BENCH_serve.json`` (committed + CI artifact + gated by
``results/check_bench.py`` + tracked by ``python -m repro.obs.regress``).

Run:  PYTHONPATH=src python -m benchmarks.serve_bench \
          [--smoke] [--repeats N] [--out P]
"""

from __future__ import annotations

import argparse
import asyncio
import json
import os
import statistics
import time

from repro.apps import ml_graphs
from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec
from repro.serve import ExploreService

from .common import FAST_MINING, emit, manifest_block, repeats_block

DEFAULT_OUT = os.path.join("results", "BENCH_serve.json")

N_CLIENTS = 4
WINDOW = 2          # apps per client; rotated -> every app named twice


def _write(result: dict, out_path: str) -> None:
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def _clients(apps):
    names = list(apps)
    return [(f"c{i}",
             {nm: apps[nm]
              for nm in (names[(i + j) % len(names)]
                         for j in range(WINDOW))})
            for i in range(N_CLIENTS)]


def _dispatches(stats) -> int:
    return stats["pnr_dispatch"] + stats["sim_dispatch"]


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats=None) -> dict:
    repeats = max(1, int(repeats)) if repeats is not None \
        else (1 if smoke else 3)
    apps = ml_graphs()
    fabric = FabricOptions(
        spec=FabricSpec(rows=16, cols=16), backend="jax",
        chains=2 if smoke else 4, sweeps=4 if smoke else 8,
        simulate=True)
    cfg = ExploreConfig(mode="per_app", mining=FAST_MINING, max_merge=2,
                        fabric=fabric, on_error="isolate")
    clients = _clients(apps)
    failures: list = []

    # -- serial reference: each client a fresh Explorer, one at a time ---
    solo_lines = {}
    samples = {"serial_s": [], "batched_s": [], "cache_hit_s": []}
    serial_dispatches = 0
    for rep in range(repeats):
        t0 = time.perf_counter()
        dispatches = 0
        for rid, capps in clients:
            ex = Explorer(capps, cfg)
            res = ex.run()
            dispatches += _dispatches(ex.stats)
            failures.extend(f.to_dict() for f in res.failures)
            if rep == 0:
                solo_lines[rid] = [json.dumps(r.to_dict())
                                   for r in res.records()]
        samples["serial_s"].append(time.perf_counter() - t0)
        serial_dispatches = dispatches

    # -- one client exploring the union: the dispatch-ratio baseline ----
    union_apps = {nm: g for _rid, capps in clients
                  for nm, g in capps.items()}
    union_ex = Explorer(union_apps, cfg)
    union_res = union_ex.run()
    failures.extend(f.to_dict() for f in union_res.failures)
    single_dispatches = _dispatches(union_ex.stats)

    # -- batched: N concurrent clients through the service --------------
    async def serve_once():
        async with ExploreService(max_batch_apps=len(union_apps),
                                  max_wait_ms=250,
                                  queue_limit=2 * N_CLIENTS) as svc:
            t0 = time.perf_counter()
            resps = await asyncio.gather(*[
                svc.explore(rid, capps, cfg) for rid, capps in clients])
            dt = time.perf_counter() - t0
            # repeat requests: answered from the response cache
            cached = await asyncio.gather(*[
                svc.explore(f"{rid}-again", capps, cfg)
                for rid, capps in clients])
            stats = svc.metrics.view()
            counters = {
                "pnr_dispatch": stats["pnr_dispatch"],
                "sim_dispatch": stats["sim_dispatch"],
                "memo_hit": sum(svc.metrics.counters("memo.hit.").values()),
                "memo_miss": sum(
                    svc.metrics.counters("memo.miss.").values()),
                "serve_requests": svc.metrics.counter("serve.requests"),
                "serve_batches": svc.metrics.counter("serve.batches"),
                "serve_cache_hits": svc.metrics.counter("serve.cache_hit"),
            }
            return dt, resps, cached, counters

    bit_identical = True
    batched_dispatches = counters = None
    for _rep in range(repeats):
        dt, resps, cached, counters = asyncio.run(serve_once())
        samples["batched_s"].append(dt)
        samples["cache_hit_s"].extend(
            c.elapsed_ms / 1e3 for c in cached)
        batched_dispatches = _dispatches(counters)
        for (rid, _capps), resp, c in zip(clients, resps, cached):
            assert resp.ok and c.ok, f"{rid}: {resp.error or c.error}"
            assert c.cached, f"{rid}: repeat request missed the cache"
            bit_identical &= resp.record_lines() == solo_lines[rid]
            bit_identical &= c.record_lines() == solo_lines[rid]
            failures.extend(resp.failures)

    serial_s = statistics.median(samples["serial_s"])
    batched_s = statistics.median(samples["batched_s"])
    cache_hit_s = statistics.median(samples["cache_hit_s"])
    speedup = serial_s / max(batched_s, 1e-9)
    dispatch_ratio = batched_dispatches / max(single_dispatches, 1)
    # cached answer vs what one batched request actually costs
    cache_speedup = (batched_s / N_CLIENTS) / max(cache_hit_s, 1e-9)

    result = {
        "bench": "serve_bench/v1",
        "suite": f"fig11_ml@16x16 x{N_CLIENTS} clients "
                 f"(rotated {WINDOW}-app windows)",
        "mode": "smoke" if smoke else "full",
        "manifest": manifest_block(),
        "n_clients": N_CLIENTS,
        "apps_per_client": WINDOW,
        "unique_apps": len(union_apps),
        "serial_s": round(serial_s, 3),
        "batched_s": round(batched_s, 3),
        "speedup": round(speedup, 2),
        "serial_dispatches": serial_dispatches,
        "single_dispatches": single_dispatches,
        "batched_dispatches": batched_dispatches,
        "dispatch_ratio": round(dispatch_ratio, 3),
        "bit_identical": bit_identical,
        "cache_hit_ms": round(cache_hit_s * 1e3, 3),
        "cache_speedup": round(cache_speedup, 1),
        "repeats": repeats_block(samples, repeats),
        # the service registry speaking, not a hand-maintained copy —
        # check_bench.py cross-checks the dispatch claims against these
        "metrics": counters,
        # check_bench.py rejects artifacts measured on degraded runs
        "failures": failures,
        "note": "serial = each client a fresh Explorer run back-to-back; "
                "batched = the same clients concurrent through "
                "ExploreService (one continuous batch over the union); "
                "fresh memo stores per repeat, jit caches warm after the "
                "first repeat for both modes; wall-clocks are medians",
    }
    _write(result, out_path)

    emit("serve_serial", serial_s * 1e6,
         f"clients={N_CLIENTS};dispatches={serial_dispatches}")
    emit("serve_batched", batched_s * 1e6,
         f"clients={N_CLIENTS};dispatches={batched_dispatches};"
         f"ratio_vs_single={dispatch_ratio:.2f}")
    emit("serve_speedup", batched_s * 1e6,
         f"{speedup:.2f}x (target >=2x);bit_identical={bit_identical};"
         f"out={out_path}")
    emit("serve_cache_hit", cache_hit_s * 1e6,
         f"{cache_speedup:.0f}x faster than exploring")

    assert bit_identical, "served records diverged from solo runs"
    assert not failures, f"benchmark run degraded: {failures}"
    assert dispatch_ratio <= 1.5, (
        f"{N_CLIENTS} clients cost {dispatch_ratio:.2f}x one client's "
        f"dispatches (must be < 1.5x)")
    if smoke:
        assert speedup > 1.0, (
            f"batched serving slower than serial ({speedup:.2f}x)")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced budget + speedup>1 assertion (CI)")
    ap.add_argument("--repeats", type=int, default=None, metavar="N",
                    help="timed repeats per mode (default: 3 full, "
                         "1 smoke); artifacts record median + IQR")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out or DEFAULT_OUT, smoke=args.smoke, repeats=args.repeats)


if __name__ == "__main__":
    main()
