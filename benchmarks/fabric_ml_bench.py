"""Fabric-level ML-suite benchmark (paper Fig. 11 apps) on a 16x16 array.

Runs the staged exploration pipeline for the four ML kernels (Conv, Block,
StrC, DS) with array-level place-and-route AND time-domain simulation
enabled — the ``pnr`` stage anneals all (variant, app) placements of a
bucket signature in one JAX dispatch — then dumps every record as
schema-versioned jsonl consumable by::

    PYTHONPATH=src python results/make_tables.py results/fabric_ml.jsonl fabric

so the EXPERIMENTS tables show the paper's per-PE columns next to the
array-accurate and *measured* (II, throughput, sim-energy) ones.

Run:  PYTHONPATH=src python -m benchmarks.fabric_ml_bench [--fast] [--out P]
"""

from __future__ import annotations

import argparse
import os
import time

from repro.apps import ml_graphs
from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec

from .common import BENCH_MINING, FAST_MINING, emit, write_records_jsonl

DEFAULT_OUT = os.path.join("results", "fabric_ml.jsonl")


def run(out_path: str = DEFAULT_OUT, fast: bool = False) -> int:
    apps = ml_graphs()
    cfg = ExploreConfig(
        mode="per_app",
        mining=FAST_MINING if fast else BENCH_MINING,
        max_merge=2 if fast else 3,
        fabric=FabricOptions(
            spec=FabricSpec(rows=16, cols=16),
            backend="jax", chains=4 if fast else 8,
            sweeps=16 if fast else 24, simulate=True))
    ex = Explorer(apps, cfg)
    t0 = time.perf_counter()
    result = ex.run()
    us = (time.perf_counter() - t0) * 1e6

    rows = write_records_jsonl(result, out_path)

    # us_per_call is the whole-suite exploration time: the pnr stage
    # anneals pairs of all four apps in shared dispatches, so per-app wall
    # time is no longer separable
    suite_us = result.elapsed_s * 1e6
    for r in rows:
        emit(f"fabric_ml_{r['app']}_{r['pe_name']}", suite_us,
             f"II={r['sim_ii']};tput={r['sim_throughput_gops']:.1f}Gops;"
             f"fab_e/op={r['fabric_energy_per_op_pj']:.4f}pJ;"
             f"sim_e/op={r['sim_energy_per_op_pj']:.4f}pJ;"
             f"verified={r['sim_verified']}")
    emit("fabric_ml_jsonl", us,
         f"rows={len(rows)};path={out_path};"
         f"pnr_dispatches={ex.stats['pnr_dispatch']}")
    return len(rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--fast", action="store_true",
                    help="reduced mining/annealing budget (CI artifact run)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out, fast=args.fast)


if __name__ == "__main__":
    main()
