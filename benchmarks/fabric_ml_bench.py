"""Fabric-level ML-suite benchmark (paper Fig. 11 apps) on a 16x16 array.

Runs the per-app DSE sweep for the four ML kernels (Conv, Block, StrC, DS)
with array-level place-and-route AND time-domain simulation enabled, then
dumps every AppCost record as jsonl consumable by::

    PYTHONPATH=src python results/make_tables.py results/fabric_ml.jsonl fabric

so the EXPERIMENTS tables show the paper's per-PE columns next to the
array-accurate and *measured* (II, throughput, sim-energy) ones.

Run:  PYTHONPATH=src python -m benchmarks.fabric_ml_bench [--fast] [--out P]
"""

from __future__ import annotations

import argparse
import os
import time

from repro.apps import ml_graphs
from repro.core import specialize_per_app
from repro.fabric import FabricOptions, FabricSpec

from .common import BENCH_MINING, FAST_MINING, emit, write_appcost_jsonl

DEFAULT_OUT = os.path.join("results", "fabric_ml.jsonl")


def run(out_path: str = DEFAULT_OUT, fast: bool = False) -> int:
    apps = ml_graphs()
    mining = FAST_MINING if fast else BENCH_MINING
    options = FabricOptions(
        spec=FabricSpec(rows=16, cols=16),
        backend="jax", chains=4 if fast else 8, sweeps=16 if fast else 24,
        simulate=True)
    t0 = time.perf_counter()
    results = specialize_per_app(apps, mining,
                                 max_merge=2 if fast else 3,
                                 fabric=options, simulate=True)
    us = (time.perf_counter() - t0) * 1e6

    app_us = {name: res.elapsed_s * 1e6 for name, res in results.items()}
    rows = write_appcost_jsonl(
        [(name, res.variants) for name, res in sorted(results.items())],
        out_path)

    # us_per_call is the measured mine+map+PnR+simulate sweep time of the
    # row's app (shared by its variants), not a fabricated per-row number
    for r in rows:
        emit(f"fabric_ml_{r['app']}_{r['pe_name']}", app_us[r["app"]],
             f"II={r['sim_ii']};tput={r['sim_throughput_gops']:.1f}Gops;"
             f"fab_e/op={r['fabric_energy_per_op_pj']:.4f}pJ;"
             f"sim_e/op={r['sim_energy_per_op_pj']:.4f}pJ;"
             f"verified={r['sim_verified']}")
    emit("fabric_ml_jsonl", us, f"rows={len(rows)};path={out_path}")
    return len(rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--fast", action="store_true",
                    help="reduced mining/annealing budget (CI artifact run)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out, fast=args.fast)


if __name__ == "__main__":
    main()
