"""Paper Fig. 10: normalized PE energy and total area for all four image
apps on PE IP (specialized for the whole image domain) vs PE Spec
(per-app) vs the baseline PE."""

from __future__ import annotations

from repro.apps import image_graphs
from repro.core import baseline_datapath, evaluate_mapping, map_application
from repro.explore import ExploreConfig, Explorer

from .common import BENCH_MINING, emit, timeit


def run() -> dict:
    apps = image_graphs()
    base = baseline_datapath()
    base_costs = {n: evaluate_mapping(base, map_application(base, g, n),
                                      "baseline") for n, g in apps.items()}

    # one Explorer memo store: the per-app sweep reuses the domain run's
    # mining/ranking artifacts instead of re-mining all four apps
    ex = Explorer(apps, ExploreConfig(mode="domain", mining=BENCH_MINING,
                                      per_app_subgraphs=2,
                                      domain_name="PE_IP"))
    us_ip, ip = timeit(lambda: ex.run().results["PE_IP"], repeats=1)
    us_sp, per_app = timeit(
        lambda: ex.with_config(mode="per_app", max_merge=3).run().results,
        repeats=1)

    out = {}
    for name in sorted(apps):
        c_base = base_costs[name]
        c_ip = ip.variants[0].costs[name]
        c_sp = per_app[name].best_variant(name).costs[name]
        e_ip = c_ip.energy_per_op_pj / c_base.energy_per_op_pj
        a_ip = c_ip.total_area_um2 / c_base.total_area_um2
        e_sp = c_sp.energy_per_op_pj / c_base.energy_per_op_pj
        a_sp = c_sp.total_area_um2 / c_base.total_area_um2
        emit(f"fig10_{name}", us_ip + us_sp,
             f"PE_IP:e={e_ip:.3f},a={a_ip:.3f};"
             f"PE_Spec:e={e_sp:.3f},a={a_sp:.3f} (normalized to baseline; "
             f"paper: IP 29.6-32.5% area, 44.5-65.25% energy savings)")
        out[name] = {"ip": (e_ip, a_ip), "spec": (e_sp, a_sp)}
    return out


if __name__ == "__main__":
    run()
