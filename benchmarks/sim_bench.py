"""Time-domain benchmark: achieved II per app + tile-step kernel speedups.

For every paper-suite app (Figs. 8/10/11) the full flow runs — map, place,
route, modulo-schedule, cycle-accurate simulate — and emits the achieved
initiation interval against the resource lower bound, the pipeline
latency, the golden-check verdict (bit-exact vs ``graphir.interp``), and
the steady-state simulation cost per pipelined iteration.

The tile-step microbenchmark compares the three ALU dispatch backends of
:mod:`repro.kernels.sim_step` on one batched step: the NumPy reference,
the vmapped ``lax.switch`` (the ``lax.scan`` reference path used by
``backend="jax"``), and the Pallas kernel (interpret mode off-TPU, so the
ratio is only meaningful on TPU hosts — emitted either way).

Run:  PYTHONPATH=src python -m benchmarks.sim_bench
"""

from __future__ import annotations

import time

import numpy as np

from repro.apps import image_graphs, ml_graphs
from repro.core import baseline_datapath, map_application
from repro.core.dse import app_ops
from repro.fabric import FabricSpec
from repro.sim import build_sim, check_against_interp, random_inputs, simulate

from .common import emit

ITERATIONS = 4
BATCH = 4


def run() -> None:
    apps = {**image_graphs(), **ml_graphs()}
    mismatches = []
    for name, app in apps.items():
        dp = baseline_datapath(app_ops(app))
        mapping = map_application(dp, app, name)
        t0 = time.perf_counter()
        prog, pnr = build_sim(dp, mapping, app, FabricSpec(rows=8, cols=8),
                              place_backend="jax", chains=8, sweeps=16)
        flow_us = (time.perf_counter() - t0) * 1e6
        inputs = random_inputs(prog, ITERATIONS, BATCH, seed=0)
        _, err, exact = check_against_interp(prog, app, inputs)
        if not (exact and err == 0.0):
            mismatches.append(name)
        emit(f"sim_schedule_{name}", flow_us,
             f"II={prog.ii};minII={prog.schedule.min_ii};"
             f"lat={prog.latency};tiles={prog.n_inst};"
             f"golden={'bit-exact' if exact and err == 0.0 else 'MISMATCH'}")

        # steady state: second call reuses the compiled scan
        simulate(prog, inputs)
        t0 = time.perf_counter()
        res = simulate(prog, inputs)
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"sim_cycle_{name}", dt / (ITERATIONS * BATCH),
             f"cycles={res.cycles};us_per_iter_per_sample="
             f"{dt / (ITERATIONS * BATCH):.1f}")

    _step_kernel_bench()
    if mismatches:
        # fail loudly so the (blocking) CI benchmark job enforces the
        # acceptance criterion: bit-match on ALL Fig. 8/10/11 apps
        raise SystemExit(f"golden MISMATCH on: {', '.join(mismatches)}")


def _step_kernel_bench() -> None:
    from repro.kernels.sim_step import (alu_step_jnp, alu_step_pallas,
                                        alu_step_reference, op_table)

    ops = op_table(["add", "sub", "mul", "min", "max", "sel", "ashr", "gt",
                    "abs", "mac"])
    rng = np.random.default_rng(0)
    b, n = 64, 512
    codes = rng.integers(0, len(ops), n).astype(np.int32)
    a = rng.standard_normal((b, n)).astype(np.float32)
    bb = rng.integers(-3, 4, (b, n)).astype(np.float32)
    c = rng.standard_normal((b, n)).astype(np.float32)

    t0 = time.perf_counter()
    alu_step_reference(codes, a, bb, c, ops)
    ref_us = (time.perf_counter() - t0) * 1e6

    np.asarray(alu_step_jnp(codes, a, bb, c, ops))          # warmup/compile
    t0 = time.perf_counter()
    np.asarray(alu_step_jnp(codes, a, bb, c, ops))
    jnp_us = (time.perf_counter() - t0) * 1e6

    np.asarray(alu_step_pallas(codes, a, bb, c, ops))       # warmup/compile
    t0 = time.perf_counter()
    np.asarray(alu_step_pallas(codes, a, bb, c, ops))
    pl_us = (time.perf_counter() - t0) * 1e6

    emit("sim_step_reference", ref_us, f"lanes={b * n}")
    emit("sim_step_jnp", jnp_us, f"ref/jnp={ref_us / jnp_us:.2f}x")
    emit("sim_step_pallas", pl_us,
         f"jnp/pallas={jnp_us / pl_us:.2f}x"
         f"{' (interpret mode: compiles on TPU)' if pl_us > jnp_us else ''}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
