"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper reference).  Run with ``PYTHONPATH=src python -m benchmarks.run``.

``--trace-dir DIR`` records every benchmark under its own tracer and
writes ``DIR/<name>.trace.json`` Chrome trace-event files (plus jax
compile events on a side track) — load them in Perfetto or summarize
with ``python -m repro.obs.report``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _benchmarks(repeats=None):
    from . import (explore_bench, fabric_camera_bench, fabric_ml_bench,
                   fig8_camera_specialization, fig10_image_pe_ip,
                   fig11_ml_pe, kernel_bench, mining_bench, pnr_bench,
                   sim_bench, table1_cgra_vs_asic)
    return [
        ("mining", mining_bench.run),          # pipeline throughput (Sec. IV)
        ("fig8_camera", fig8_camera_specialization.run),   # Fig. 8
        ("fig10_image_pe_ip", fig10_image_pe_ip.run),      # Fig. 10
        ("fig11_ml_pe", fig11_ml_pe.run),                  # Fig. 11
        ("table1", table1_cgra_vs_asic.run),               # Table I
        ("kernels", kernel_bench.run),  # TPU-adaptation kernel statistics
        # placer scaling (delta vs full), median of --repeats
        ("pnr", lambda: pnr_bench.run(repeats=repeats)),
        ("sim", sim_bench.run),         # time domain: achieved II + golden
        # batched vs serial pnr stage
        ("explore", lambda: explore_bench.run(smoke=True, repeats=repeats)),
        # Fig. 11 @ 16x16 -> records jsonl
        ("fabric_ml", lambda: fabric_ml_bench.run(fast=True)),
        # camera @ auto-fit 18x17 fabric
        ("fabric_camera", lambda: fabric_camera_bench.run(fast=True)),
    ]


def _run_traced(name, fn, trace_dir: str) -> None:
    """One fresh tracer per benchmark -> ``trace_dir/<name>.trace.json``."""
    from repro import obs
    obs.enable_tracing()
    obs.enable_telemetry()
    obs.jaxprof.enable()
    try:
        with obs.span(name):
            fn()
    finally:
        tracer = obs.disable_tracing()
        obs.enable_telemetry(False)
        obs.jaxprof.disable()
    path = os.path.join(trace_dir, f"{name}.trace.json")
    tracer.write_chrome(path)
    print(f"# trace -> {path}", file=sys.stderr)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="write one Chrome trace per benchmark into DIR")
    ap.add_argument("--repeats", type=int, default=None, metavar="N",
                    help="timed repeats for the repeat-aware benches "
                         "(pnr/explore); their BENCH jsons record "
                         "median + IQR")
    args = ap.parse_args(argv)
    if args.trace_dir:
        os.makedirs(args.trace_dir, exist_ok=True)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in _benchmarks(repeats=args.repeats):
        if args.trace_dir:
            _run_traced(name, fn, args.trace_dir)
        else:
            fn()
    print(f"# total benchmark wall time: {time.time()-t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
