"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper reference).  Run with ``PYTHONPATH=src python -m benchmarks.run``.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (explore_bench, fabric_camera_bench, fabric_ml_bench,
                   fig8_camera_specialization, fig10_image_pe_ip,
                   fig11_ml_pe, kernel_bench, mining_bench, pnr_bench,
                   sim_bench, table1_cgra_vs_asic)
    print("name,us_per_call,derived")
    t0 = time.time()
    mining_bench.run()          # pipeline throughput (Sec. IV)
    fig8_camera_specialization.run()   # Fig. 8
    fig10_image_pe_ip.run()     # Fig. 10
    fig11_ml_pe.run()           # Fig. 11
    table1_cgra_vs_asic.run()   # Table I
    kernel_bench.run()          # TPU-adaptation kernel statistics
    pnr_bench.run()             # placer scaling (delta vs full) + harris
    sim_bench.run()             # time domain: achieved II + golden check
    explore_bench.run(smoke=True)      # batched vs serial pnr stage
    fabric_ml_bench.run(fast=True)     # Fig. 11 @ 16x16 -> records jsonl
    fabric_camera_bench.run(fast=True)  # camera @ auto-fit 18x17 fabric
    print(f"# total benchmark wall time: {time.time()-t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
