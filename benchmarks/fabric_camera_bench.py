"""Fabric-level camera-pipeline benchmark (paper Fig. 8 app) on an
auto-fit array (~18x17 for the baseline PE).

The camera pipeline is the largest app in the suite — its baseline
mapping needs ~300 tiles, which made array-level evaluation minutes of
annealing budget with full-recompute move scoring (a former ROADMAP open
item).  With the delta-scored placer driven through the staged
exploration pipeline the whole PE1..PE5 specialization sweep runs at
array level in seconds; every record is dumped as schema-versioned jsonl
consumable by::

    PYTHONPATH=src python results/make_tables.py results/fabric_camera.jsonl fabric

Run:  PYTHONPATH=src python -m benchmarks.fabric_camera_bench
          [--fast] [--simulate] [--out P]
"""

from __future__ import annotations

import argparse
import os
import time

from repro.explore import ExploreConfig, Explorer
from repro.fabric import FabricOptions, FabricSpec

from .common import BENCH_MINING, FAST_MINING, emit, write_records_jsonl
from .fig8_camera_specialization import camera_app

DEFAULT_OUT = os.path.join("results", "fabric_camera.jsonl")


def run(out_path: str = DEFAULT_OUT, fast: bool = False,
        simulate: bool = False) -> int:
    app = camera_app()
    # the spec is a seed: the pnr stage auto-fits it per variant, so the
    # baseline PE lands on the 18x17 grid the ROADMAP calls out and the
    # specialized variants shrink with their instance counts
    cfg = ExploreConfig(
        mode="per_app",
        mining=FAST_MINING if fast else BENCH_MINING,
        max_merge=2 if fast else 4,
        fabric=FabricOptions(
            spec=FabricSpec(rows=2, cols=2),
            backend="jax", score_mode="delta",
            chains=2 if fast else 4, sweeps=8 if fast else 16,
            simulate=simulate))
    ex = Explorer({"camera": app}, cfg)
    t0 = time.perf_counter()
    result = ex.run()
    us = (time.perf_counter() - t0) * 1e6

    res = result.results["camera"]
    rows = write_records_jsonl(result, out_path)

    for v in res.variants:
        r = v.costs["camera"]
        fc = v.fabric_costs["camera"]
        derived = (f"grid={fc.cols}x{fc.rows};"
                   f"util={r.fabric_utilization:.2f};"
                   f"wl={r.fabric_wirelength};"
                   f"fab_e/op={r.fabric_energy_per_op_pj:.4f}pJ")
        if simulate:
            derived += (f";II={r.sim_ii}"
                        f";sim_e/op={r.sim_energy_per_op_pj:.4f}pJ"
                        f";verified={r.sim_verified}")
        emit(f"fabric_camera_{v.name}", res.elapsed_s * 1e6, derived)
    emit("fabric_camera_jsonl", us, f"rows={len(rows)};path={out_path}")
    return len(rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--fast", action="store_true",
                    help="reduced mining/annealing budget (CI artifact run)")
    ap.add_argument("--simulate", action="store_true",
                    help="also modulo-schedule + cycle-accurately simulate "
                         "every variant (adds the sim_* columns)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out, fast=args.fast, simulate=args.simulate)


if __name__ == "__main__":
    main()
