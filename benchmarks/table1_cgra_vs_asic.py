"""Paper Table I: ML-specialized CGRA vs a generic CGRA and a Simba-class
vector-MAC ASIC bound (CGRA-level energy per op, memory tiles included)."""

from __future__ import annotations

from repro.apps import ml_graphs
from repro.core import (baseline_datapath, domain_pe, evaluate_mapping,
                        map_application)
from repro.core.costmodel import vector_mac_asic_energy_per_op_pj

from .common import BENCH_MINING, emit, timeit


def run() -> dict:
    apps = ml_graphs()
    base = baseline_datapath()
    us, ml = timeit(lambda: domain_pe(apps, BENCH_MINING,
                                      per_app_subgraphs=2,
                                      domain_name="PE_ML"), repeats=1)
    # conv is the ResNet-dominant kernel: use it for the Table I comparison
    name = "conv"
    g = apps[name]
    c_base = evaluate_mapping(base, map_application(base, g, name), "base")
    c_ml = ml.variants[0].costs[name]
    asic = vector_mac_asic_energy_per_op_pj()

    reduction = 1 - c_ml.cgra_energy_per_op_pj / c_base.cgra_energy_per_op_pj
    gap = c_ml.cgra_energy_per_op_pj / asic
    emit("table1_generic_cgra", us,
         f"cgra_e/op={c_base.cgra_energy_per_op_pj:.4f}pJ")
    emit("table1_ml_cgra", us,
         f"cgra_e/op={c_ml.cgra_energy_per_op_pj:.4f}pJ;"
         f"reduction={reduction*100:.1f}% (paper: 22.1%)")
    emit("table1_vector_mac_asic", us,
         f"e/op={asic:.4f}pJ;cgra_vs_asic_gap={gap:.2f}x "
         f"(paper: specialized CGRA nears ASIC efficiency)")
    return {"base": c_base.cgra_energy_per_op_pj,
            "ml": c_ml.cgra_energy_per_op_pj, "asic": asic,
            "reduction": reduction, "gap": gap}


if __name__ == "__main__":
    run()
