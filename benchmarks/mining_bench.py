"""DSE-pipeline throughput: mining + merging runtime per application."""

from __future__ import annotations

from repro.apps import image_graphs, ml_graphs
from repro.core import mine_and_rank

from .common import FAST_MINING, emit, timeit


def run() -> dict:
    out = {}
    for name, g in {**image_graphs(), **ml_graphs()}.items():
        us, ranked = timeit(lambda: mine_and_rank(g, FAST_MINING), repeats=1)
        top = ranked[0] if ranked else None
        emit(f"mining_{name}", us,
             f"nodes={g.num_compute_nodes()};patterns={len(ranked)};"
             f"top_mis={top.mis_size if top else 0}")
        out[name] = len(ranked)
    return out


if __name__ == "__main__":
    run()
